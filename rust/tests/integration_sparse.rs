//! Dense-vs-sparse equivalence: CSR storage is a representation change,
//! not a math change. Gram blocks must agree across storages within
//! float tolerance for every kernel family and SIMD tier; clustering
//! over the two storages must produce identical labels on separable
//! data; and the CSR source must compose with the tiled/budgeted and
//! sharded pipelines with their bit-identity guarantees intact.
use dkkm::cluster::minibatch::{MiniBatchConfig, MiniBatchKernelKMeans, NativeBackend};
use dkkm::data::synthetic_rcv1_sparse;
use dkkm::distributed::ShardedBackend;
use dkkm::kernels::microkernel::{self, PackedPanel};
use dkkm::kernels::VecGram;
use dkkm::linalg::{simd, Mat};
use dkkm::prelude::*;
use dkkm::util::rng::Rng;

/// Sparse blobs: `classes` clusters with disjoint word blocks, `n_per`
/// documents each, ~6 words per document. Cleanly separable, so dense
/// and CSR clustering must agree exactly despite float noise.
fn sparse_blobs(seed: u64, n_per: usize, classes: usize) -> (CsrMat, Vec<usize>) {
    let words_per_class = 16usize;
    let vocab = classes * words_per_class + 40; // trailing words stay unused
    let mut rng = Rng::new(seed);
    let mut rows = Vec::with_capacity(n_per * classes);
    let mut labels = Vec::with_capacity(n_per * classes);
    for c in 0..classes {
        for _ in 0..n_per {
            let mut doc = Vec::with_capacity(6);
            let mut norm = 0.0f32;
            for _ in 0..6 {
                let w = c * words_per_class + rng.below(words_per_class);
                let v = 0.5 + rng.f32();
                norm += v * v;
                doc.push((w, v));
            }
            let norm = norm.sqrt();
            for (_, v) in doc.iter_mut() {
                *v /= norm;
            }
            rows.push(doc);
            labels.push(c);
        }
    }
    (CsrMat::from_rows(vocab, rows), labels)
}

#[test]
fn blocks_agree_across_storages_kernels_and_tiers() {
    let (csr, _) = sparse_blobs(0, 20, 4);
    let dense = csr.to_dense();
    let rows: Vec<usize> = (0..csr.rows()).step_by(3).collect();
    let cols: Vec<usize> = (1..csr.rows()).step_by(5).collect();
    let xn = csr.sq_norms().to_vec();
    let yn: Vec<f32> = cols.iter().map(|&j| xn[j]).collect();
    for kernel in [
        KernelFn::Linear,
        KernelFn::Rbf { gamma: 0.7 },
        KernelFn::Poly { degree: 3, c: 0.5 },
    ] {
        let packed_dense = PackedPanel::pack_gather(&dense, &cols);
        let packed_csr = PackedPanel::pack_gather_csr(&csr, &cols);
        for tier in simd::supported_tiers() {
            let mut a = vec![0.0f32; rows.len() * cols.len()];
            let mut b = vec![0.0f32; rows.len() * cols.len()];
            microkernel::fill_gram_rows(
                tier,
                &dense,
                &rows,
                &packed_dense,
                &xn,
                &yn,
                kernel,
                &mut a,
            );
            microkernel::fill_gram_rows_csr(
                tier,
                &csr,
                &rows,
                &packed_csr,
                &xn,
                &yn,
                kernel,
                &mut b,
            );
            for (g, w) in b.iter().zip(&a) {
                assert!((g - w).abs() < 1e-4, "{tier} {kernel:?}: {g} vs {w}");
            }
        }
    }
}

#[test]
fn degenerate_shapes_agree_across_storages() {
    // empty documents, an all-vocab (density 1) document, and a corpus
    // whose density is ~1 overall — the CSR path must serve them all
    let vocab = 12usize;
    let mut rows: Vec<Vec<(usize, f32)>> = vec![Vec::new(), Vec::new()];
    rows.push((0..vocab).map(|w| (w, 0.3)).collect());
    let mut rng = Rng::new(9);
    for _ in 0..17 {
        rows.push((0..vocab).map(|w| (w, rng.normal32(0.0, 1.0))).collect());
    }
    let csr = CsrMat::from_rows(vocab, rows);
    assert!(csr.density() > 0.8, "meant to stress the dense end");
    let dense = csr.to_dense();
    let n = csr.rows();
    let all: Vec<usize> = (0..n).collect();
    for kernel in [KernelFn::Linear, KernelFn::Rbf { gamma: 0.2 }] {
        let a = VecGram::new(dense.clone(), kernel, 2).block_mat(&all, &all);
        let b = VecGram::from_csr(csr.clone(), kernel, 2).block_mat(&all, &all);
        for (g, w) in b.data().iter().zip(a.data()) {
            assert!((g - w).abs() < 1e-4, "{kernel:?}: {g} vs {w}");
        }
    }
    // two empty docs are at distance 0: RBF says identical
    let g = VecGram::from_csr(csr, KernelFn::Rbf { gamma: 0.2 }, 1);
    let k = g.block_mat(&[0, 1], &[0, 1]);
    for v in k.data() {
        assert!((v - 1.0).abs() < 1e-6, "empty-doc kernel {v}");
    }
}

#[test]
fn clustering_labels_match_across_storages() {
    let (csr, truth) = sparse_blobs(1, 40, 4);
    let kernel = KernelFn::Rbf { gamma: 1.0 };
    let dense_g = VecGram::new(csr.to_dense(), kernel, 2);
    let sparse_g = VecGram::from_csr(csr, kernel, 2);
    let cfg = MiniBatchConfig::new(4, 2);
    let dense_run = MiniBatchKernelKMeans::new(cfg.clone(), &NativeBackend).run(&dense_g).unwrap();
    let sparse_run = MiniBatchKernelKMeans::new(cfg, &NativeBackend).run(&sparse_g).unwrap();
    assert_eq!(dense_run.labels, sparse_run.labels, "storage changed the clustering");
    assert_eq!(dense_run.medoids, sparse_run.medoids);
    // and both recover the planted blobs
    assert!(accuracy(&sparse_run.labels, &truth) > 0.95);
}

#[test]
fn csr_source_composes_with_tiles_and_shards_bit_identically() {
    let (csr, _) = sparse_blobs(2, 40, 4); // n = 160, B = 2 -> 80-row panels
    let g = VecGram::from_csr(csr, KernelFn::Rbf { gamma: 1.0 }, 2);
    let base = MiniBatchConfig::new(4, 2);
    let whole = MiniBatchKernelKMeans::new(base.clone(), &NativeBackend).run(&g).unwrap();
    // budgeted tiles over the CSR source: pure scheduling, bit-identical
    let mut tiled_cfg = base.clone();
    tiled_cfg.memory_budget = Some(8 * 1024);
    let tiled = MiniBatchKernelKMeans::new(tiled_cfg, &NativeBackend).run(&g).unwrap();
    assert_eq!(whole.labels, tiled.labels, "tiled CSR diverged");
    assert_eq!(whole.medoids, tiled.medoids);
    assert!(tiled.pipeline.tiles > 2, "{:?}", tiled.pipeline);
    assert!(tiled.pipeline.peak_resident_bytes <= 8 * 1024, "{:?}", tiled.pipeline);
    // sharded nodes over the CSR source match the native schedule
    for p in [2usize, 5] {
        let sharded =
            MiniBatchKernelKMeans::new(base.clone(), &ShardedBackend::new(p)).run(&g).unwrap();
        assert_eq!(whole.labels, sharded.labels, "sharded:{p} CSR diverged");
        assert_eq!(whole.medoids, sharded.medoids);
    }
}

#[test]
fn sparse_spec_round_trips_and_reports_storage() {
    let spec: DatasetSpec = "rcv1:400:6:32:sparse".parse().unwrap();
    let want = DatasetSpec::Rcv1 { n: 400, classes: 6, dim: 32, storage: RcvStorage::Sparse };
    assert_eq!(spec, want);
    assert_eq!(spec.to_string(), "rcv1:400:6:32:sparse");
    // dense specs keep the historical 3-number arity
    let dense: DatasetSpec = "rcv1:400:6:32".parse().unwrap();
    assert_eq!(dense.to_string(), "rcv1:400:6:32");

    let report = Experiment::on(spec).clusters(6).batches(2).build().unwrap().fit().unwrap();
    assert_eq!(report.storage, "csr");
    assert!(report.test_accuracy.is_some(), "sparse spec keeps a held-out split");
    let parsed = dkkm::util::json::Json::parse(&report.to_json().to_string()).unwrap();
    assert_eq!(parsed.get("storage").and_then(|v| v.as_str()), Some("csr"));
}

#[test]
fn sparse_and_dense_rcv1_share_the_corpus() {
    // same seed, same documents: the sparse dataset's labels must equal
    // the dense materialization's labels (features differ by projection)
    let sparse = synthetic_rcv1_sparse(&mut Rng::new(11), 250, 5, 2000);
    let dense = dkkm::data::synthetic_rcv1(&mut Rng::new(11), 250, 5, 2000, 16);
    assert_eq!(sparse.y, dense.y);
    assert_eq!(sparse.n(), dense.n());
}

#[test]
fn pairwise_routing_matches_reference_oracle() {
    // the micro-kernel-routed sq_dists_block vs the retained oracle
    let mut rng = Rng::new(3);
    let x = Mat::from_fn(40, 23, |_, _| rng.normal32(0.0, 1.0));
    let y = Mat::from_fn(17, 23, |_, _| rng.normal32(0.0, 1.0));
    let got = dkkm::linalg::sq_dists_block(4, &x, &y);
    let want = dkkm::linalg::sq_dists_block_reference(4, &x, &y);
    for (g, w) in got.data().iter().zip(want.data()) {
        assert!((g - w).abs() < 1e-3, "{g} vs {w}");
    }
    // thread invariance survives the routing
    let single = dkkm::linalg::sq_dists_block(1, &x, &y);
    assert_eq!(got.data(), single.data());
}
