//! Exact full-batch kernel k-means (paper §2) — the baseline every
//! approximation is measured against, and the B=1, s=1 special case of
//! the mini-batch algorithm.
use crate::linalg::Mat;

use super::assign::{argmin_labels, block_cost, similarity_f, ClusterStats};

/// Result of a full-batch run.
#[derive(Clone, Debug)]
pub struct FullResult {
    pub labels: Vec<usize>,
    /// Cost Omega(W) after every iteration (kernel-trick form).
    pub cost_history: Vec<f64>,
    pub iterations: usize,
    pub converged: bool,
}

/// Iterate Eq.4 on a dense kernel matrix until the labels reach a fixed
/// point (Bottou-Bengio guarantees monotone cost) or `max_iter`.
pub fn full_kernel_kmeans(
    k: &Mat,
    init_labels: &[usize],
    c: usize,
    max_iter: usize,
) -> FullResult {
    let n = k.rows();
    assert_eq!(k.cols(), n, "kernel matrix must be square");
    assert_eq!(init_labels.len(), n);
    let diag: Vec<f32> = (0..n).map(|i| k.at(i, i)).collect();
    let mut labels = init_labels.to_vec();
    let mut cost_history = Vec::new();
    let mut converged = false;
    let mut iterations = 0;
    for _ in 0..max_iter {
        iterations += 1;
        let stats = ClusterStats::compute(k, &labels, c);
        let f = similarity_f(k, &labels, &stats);
        cost_history.push(block_cost(&diag, &f, &labels, &stats));
        let new_labels = argmin_labels(&f, &stats);
        if new_labels == labels {
            converged = true;
            break;
        }
        labels = new_labels;
    }
    FullResult { labels, cost_history, iterations, converged }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{GramSource, KernelFn, VecGram};
    use crate::util::rng::Rng;

    fn blobs(seed: u64, per: usize, c: usize, spread: f32) -> (Mat, Vec<usize>) {
        let mut rng = Rng::new(seed);
        let n = per * c;
        let mut truth = vec![0usize; n];
        let x = Mat::from_fn(n, 2, |r, col| {
            let blob = r % c;
            truth[r] = blob;
            let center = [(blob % 2) as f32 * spread, (blob / 2) as f32 * spread];
            rng.normal32(center[col], 1.0)
        });
        (x, truth)
    }

    fn gram(x: Mat) -> Mat {
        let g = VecGram::new(x, KernelFn::Rbf { gamma: 0.05 }, 2);
        let idx: Vec<usize> = (0..g.n()).collect();
        g.block_mat(&idx, &idx)
    }

    #[test]
    fn converges_and_cost_monotone() {
        let (x, _) = blobs(0, 30, 4, 20.0);
        let k = gram(x);
        let mut rng = Rng::new(1);
        let init: Vec<usize> = (0..120).map(|_| rng.below(4)).collect();
        let res = full_kernel_kmeans(&k, &init, 4, 50);
        assert!(res.converged);
        for w in res.cost_history.windows(2) {
            assert!(w[1] <= w[0] + 1e-3, "cost rose {w:?}");
        }
    }

    #[test]
    fn recovers_separated_blobs() {
        let (x, truth) = blobs(2, 25, 4, 40.0);
        let k = gram(x);
        // seed with one point from each blob to avoid degenerate inits
        let mut init = vec![0usize; 100];
        for (i, item) in init.iter_mut().enumerate() {
            *item = truth[i]; // start from truth perturbed
        }
        init[0] = 1;
        init[50] = 3;
        let res = full_kernel_kmeans(&k, &init, 4, 50);
        // same-blob samples share a label
        for i in 0..100 {
            for j in 0..100 {
                if truth[i] == truth[j] {
                    assert_eq!(res.labels[i], res.labels[j]);
                }
            }
        }
    }

    #[test]
    fn fixed_point_is_stable() {
        let (x, _) = blobs(3, 20, 3, 15.0);
        let k = gram(x);
        let mut rng = Rng::new(4);
        let init: Vec<usize> = (0..60).map(|_| rng.below(3)).collect();
        let res = full_kernel_kmeans(&k, &init, 3, 100);
        let again = full_kernel_kmeans(&k, &res.labels, 3, 5);
        assert_eq!(again.labels, res.labels);
        assert_eq!(again.iterations, 1);
    }

    #[test]
    fn single_cluster_degenerate() {
        let (x, _) = blobs(5, 10, 2, 5.0);
        let k = gram(x);
        let res = full_kernel_kmeans(&k, &vec![0; 20], 1, 10);
        assert!(res.converged);
        assert!(res.labels.iter().all(|&u| u == 0));
    }
}
