//! Device-thread PJRT runtime.
//!
//! The `xla` crate's PJRT handles wrap raw pointers (neither `Send` nor
//! `Sync`), so all PJRT state lives on one dedicated **device thread** —
//! which is also exactly the paper's offload architecture (Fig.3: "a CPU
//! thread is bound to the device ... responsible for host-device data
//! transfer and device control"). Host-side callers talk to it through a
//! synchronous request channel carrying plain buffers; executables are
//! compiled on first use and cached by artifact name.
use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::Mutex;

use crate::linalg::Mat;
use crate::util::error::{Error, Result};

use super::manifest::{ArtifactEntry, DType, Manifest};

/// A plain host tensor crossing the host/device channel.
#[derive(Clone, Debug)]
pub enum Tensor {
    F32 { data: Vec<f32>, dims: Vec<usize> },
    I32 { data: Vec<i32>, dims: Vec<usize> },
}

impl Tensor {
    pub fn from_mat(m: &Mat) -> Tensor {
        Tensor::F32 { data: m.data().to_vec(), dims: vec![m.rows(), m.cols()] }
    }

    pub fn scalar2d(v: f32) -> Tensor {
        Tensor::F32 { data: vec![v], dims: vec![1, 1] }
    }

    pub fn row(v: Vec<f32>) -> Tensor {
        let n = v.len();
        Tensor::F32 { data: v, dims: vec![1, n] }
    }

    pub fn dims(&self) -> &[usize] {
        match self {
            Tensor::F32 { dims, .. } | Tensor::I32 { dims, .. } => dims,
        }
    }

    pub fn f32_data(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => Err(Error::Runtime("expected f32 tensor".into())),
        }
    }

    pub fn i32_data(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            _ => Err(Error::Runtime("expected i32 tensor".into())),
        }
    }
}

struct Request {
    name: String,
    inputs: Vec<Tensor>,
    reply: mpsc::Sender<Result<Vec<Tensor>>>,
}

/// Handle to the device thread. Cheap to share (`Sync`); dropping the
/// last handle shuts the thread down.
pub struct PjrtRuntime {
    tx: Mutex<Option<mpsc::Sender<Request>>>,
    join: Mutex<Option<std::thread::JoinHandle<()>>>,
    manifest: Manifest,
}

impl PjrtRuntime {
    /// Spawn the device thread over the given artifact directory.
    pub fn start(manifest: Manifest) -> Result<PjrtRuntime> {
        let (tx, rx) = mpsc::channel::<Request>();
        let entries = manifest.entries.clone();
        let join = std::thread::Builder::new()
            .name("pjrt-device".into())
            .spawn(move || device_main(entries, rx))
            .map_err(|e| Error::Runtime(format!("cannot spawn device thread: {e}")))?;
        Ok(PjrtRuntime {
            tx: Mutex::new(Some(tx)),
            join: Mutex::new(Some(join)),
            manifest,
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Execute an artifact by name. Shapes are validated against the
    /// manifest before crossing the channel.
    pub fn execute(&self, name: &str, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        let entry = self.manifest.find(name)?;
        validate_inputs(entry, &inputs)?;
        let (reply_tx, reply_rx) = mpsc::channel();
        {
            let guard = self.tx.lock().unwrap();
            let tx = guard
                .as_ref()
                .ok_or_else(|| Error::Runtime("runtime shut down".into()))?;
            tx.send(Request { name: name.to_string(), inputs, reply: reply_tx })
                .map_err(|_| Error::Runtime("device thread died".into()))?;
        }
        reply_rx
            .recv()
            .map_err(|_| Error::Runtime("device thread dropped reply".into()))?
    }
}

impl Drop for PjrtRuntime {
    fn drop(&mut self) {
        // close the channel, then join so PJRT teardown happens cleanly
        *self.tx.lock().unwrap() = None;
        if let Some(join) = self.join.lock().unwrap().take() {
            let _ = join.join();
        }
    }
}

fn validate_inputs(entry: &ArtifactEntry, inputs: &[Tensor]) -> Result<()> {
    if inputs.len() != entry.inputs.len() {
        return Err(Error::Runtime(format!(
            "{}: expected {} inputs, got {}",
            entry.name,
            entry.inputs.len(),
            inputs.len()
        )));
    }
    for (i, (tensor, (dt, dims))) in inputs.iter().zip(&entry.inputs).enumerate() {
        let ok_type = matches!(
            (tensor, dt),
            (Tensor::F32 { .. }, DType::F32) | (Tensor::I32 { .. }, DType::I32)
        );
        if !ok_type {
            return Err(Error::Runtime(format!(
                "{}: input {i} dtype mismatch",
                entry.name
            )));
        }
        if tensor.dims() != dims.as_slice() {
            return Err(Error::Runtime(format!(
                "{}: input {i} shape {:?} != manifest {:?}",
                entry.name,
                tensor.dims(),
                dims
            )));
        }
    }
    Ok(())
}

// ----------------------------------------------------------------------
// device thread

fn device_main(entries: Vec<ArtifactEntry>, rx: mpsc::Receiver<Request>) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            // reply with errors until the channel closes
            for req in rx {
                let _ = req
                    .reply
                    .send(Err(Error::Runtime(format!("PJRT client failed: {e}"))));
            }
            return;
        }
    };
    let by_name: HashMap<String, ArtifactEntry> =
        entries.into_iter().map(|e| (e.name.clone(), e)).collect();
    let mut cache: HashMap<String, xla::PjRtLoadedExecutable> = HashMap::new();

    for req in rx {
        let result = serve(&client, &by_name, &mut cache, &req);
        let _ = req.reply.send(result);
    }
}

fn serve(
    client: &xla::PjRtClient,
    by_name: &HashMap<String, ArtifactEntry>,
    cache: &mut HashMap<String, xla::PjRtLoadedExecutable>,
    req: &Request,
) -> Result<Vec<Tensor>> {
    let entry = by_name
        .get(&req.name)
        .ok_or_else(|| Error::Runtime(format!("unknown artifact {}", req.name)))?;
    if !cache.contains_key(&req.name) {
        let path = entry.file.to_string_lossy().to_string();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| Error::Runtime(format!("load {path}: {e}")))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| Error::Runtime(format!("compile {}: {e}", req.name)))?;
        cache.insert(req.name.clone(), exe);
    }
    let exe = cache.get(&req.name).expect("just inserted");

    let mut literals = Vec::with_capacity(req.inputs.len());
    for t in &req.inputs {
        let lit = match t {
            Tensor::F32 { data, dims } => {
                let dims: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data)
                    .reshape(&dims)
                    .map_err(|e| Error::Runtime(format!("reshape input: {e}")))?
            }
            Tensor::I32 { data, dims } => {
                let dims: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data)
                    .reshape(&dims)
                    .map_err(|e| Error::Runtime(format!("reshape input: {e}")))?
            }
        };
        literals.push(lit);
    }
    let result = exe
        .execute::<xla::Literal>(&literals)
        .map_err(|e| Error::Runtime(format!("execute {}: {e}", req.name)))?;
    let out_lit = result[0][0]
        .to_literal_sync()
        .map_err(|e| Error::Runtime(format!("fetch result: {e}")))?;
    // the AOT path lowers with return_tuple=True
    let parts = out_lit
        .to_tuple()
        .map_err(|e| Error::Runtime(format!("untuple: {e}")))?;
    let mut outputs = Vec::with_capacity(parts.len());
    for (part, (dt, dims)) in parts.into_iter().zip(&entry.outputs) {
        let t = match dt {
            DType::F32 => Tensor::F32 {
                data: part
                    .to_vec::<f32>()
                    .map_err(|e| Error::Runtime(format!("read f32 out: {e}")))?,
                dims: dims.clone(),
            },
            DType::I32 => Tensor::I32 {
                data: part
                    .to_vec::<i32>()
                    .map_err(|e| Error::Runtime(format!("read i32 out: {e}")))?,
                dims: dims.clone(),
            },
        };
        outputs.push(t);
    }
    Ok(outputs)
}

#[cfg(test)]
pub mod tests {
    use super::*;
    use std::path::PathBuf;
    use std::sync::{Arc, OnceLock};

    /// One shared runtime for all tests in the binary: PJRT CPU clients
    /// are heavyweight and the device thread serializes access anyway.
    /// `None` when the artifact manifest is absent — artifact-dependent
    /// tests skip themselves instead of failing a checkout that never
    /// ran `make artifacts`.
    pub fn try_shared_runtime() -> Option<Arc<PjrtRuntime>> {
        static RT: OnceLock<Option<Arc<PjrtRuntime>>> = OnceLock::new();
        RT.get_or_init(|| {
            let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
            let manifest = Manifest::load(&dir).ok()?;
            Some(Arc::new(PjrtRuntime::start(manifest).expect("runtime start")))
        })
        .clone()
    }

    /// Panicking variant for callers that require the artifacts.
    pub fn shared_runtime() -> Arc<PjrtRuntime> {
        try_shared_runtime().expect("run `make artifacts`")
    }

    macro_rules! runtime_or_skip {
        () => {
            match try_shared_runtime() {
                Some(rt) => rt,
                None => {
                    eprintln!("skipping: no artifacts (run `make artifacts`)");
                    return;
                }
            }
        };
    }

    #[test]
    fn executes_rbf_artifact_matches_native() {
        let rt = runtime_or_skip!();
        let m = 256;
        let d = 64;
        let mut rng = crate::util::rng::Rng::new(0);
        let x = Mat::from_fn(m, d, |_, _| rng.normal32(0.0, 1.0));
        let y = Mat::from_fn(m, d, |_, _| rng.normal32(0.0, 1.0));
        let gamma = 0.05f32;
        let out = rt
            .execute(
                "rbf_t256_d64",
                vec![Tensor::from_mat(&x), Tensor::from_mat(&y), Tensor::scalar2d(gamma)],
            )
            .unwrap();
        let k = out[0].f32_data().unwrap();
        // native oracle
        for check in [(0usize, 0usize), (10, 200), (255, 255), (13, 77)] {
            let (i, j) = check;
            let d2: f32 = x
                .row(i)
                .iter()
                .zip(y.row(j))
                .map(|(a, b)| (a - b) * (a - b))
                .sum();
            let want = (-gamma * d2).exp();
            let got = k[i * m + j];
            assert!((got - want).abs() < 1e-4, "[{i},{j}] {got} vs {want}");
        }
    }

    #[test]
    fn shape_validation_rejects_bad_inputs() {
        let rt = runtime_or_skip!();
        let bad = rt.execute(
            "rbf_t256_d64",
            vec![
                Tensor::F32 { data: vec![0.0; 10], dims: vec![10, 1] },
                Tensor::F32 { data: vec![0.0; 10], dims: vec![10, 1] },
                Tensor::scalar2d(1.0),
            ],
        );
        assert!(bad.is_err());
        let msg = format!("{}", bad.unwrap_err());
        assert!(msg.contains("shape"), "{msg}");
    }

    #[test]
    fn unknown_artifact_rejected() {
        let rt = runtime_or_skip!();
        assert!(rt.execute("nope", vec![]).is_err());
    }

    #[test]
    fn golden_vectors_roundtrip() {
        // the aot.py golden set: inputs + oracle outputs dumped at
        // artifact build time; full end-to-end PJRT numerics check
        let rt = runtime_or_skip!();
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        let read_f32 = |p: &str| -> Vec<f32> {
            let bytes = std::fs::read(dir.join(p)).expect(p);
            bytes
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect()
        };
        let x = read_f32("golden/rbf_t256_d64.x.bin");
        let y = read_f32("golden/rbf_t256_d64.y.bin");
        let gamma = read_f32("golden/rbf_t256_d64.gamma.bin");
        let want = read_f32("golden/rbf_t256_d64.out.bin");
        let out = rt
            .execute(
                "rbf_t256_d64",
                vec![
                    Tensor::F32 { data: x, dims: vec![256, 64] },
                    Tensor::F32 { data: y, dims: vec![256, 64] },
                    Tensor::F32 { data: gamma, dims: vec![1, 1] },
                ],
            )
            .unwrap();
        let got = out[0].f32_data().unwrap();
        assert_eq!(got.len(), want.len());
        let max_err = got
            .iter()
            .zip(&want)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err < 2e-5, "max err {max_err}");
    }
}
