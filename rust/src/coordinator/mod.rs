//! Top-level coordinator: configuration, the Eq.19 memory planner, and
//! the end-to-end runner that wires datasets -> Gram sources -> the
//! mini-batch algorithm -> metrics reports. This is what `main.rs` (the
//! CLI), the examples and the benches drive.
pub mod config;
pub mod memory;
pub mod runner;

pub use config::{BackendChoice, DatasetSpec, RunConfig};
pub use memory::{b_min, footprint_bytes, paper_b_min};
pub use runner::{run_experiment, RunReport};
