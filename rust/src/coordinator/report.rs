//! Experiment reports: metrics, timings, and honest engine provenance.
use crate::cluster::MiniBatchResult;
use crate::util::json::Json;

/// Which engine a session ran on — requested vs actually used, plus the
/// reason whenever the two differ (e.g. the PJRT Gram path degraded to
/// native because no artifact matches the feature dimension).
#[derive(Clone, Debug, PartialEq)]
pub struct EngineReport {
    /// Engine the configuration asked for (registry name).
    pub requested: String,
    /// Engine that actually evaluated the Gram blocks.
    pub used: String,
    /// Why the engine degraded, when `used != requested`.
    pub fallback: Option<String>,
}

impl EngineReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("requested", Json::str(&self.requested)),
            ("used", Json::str(&self.used)),
            (
                "fallback",
                self.fallback
                    .as_deref()
                    .map(Json::str)
                    .unwrap_or(Json::Null),
            ),
        ])
    }
}

/// Everything a bench or the CLI needs from one experiment.
#[derive(Clone, Debug)]
pub struct RunReport {
    pub c_used: usize,
    pub gamma: f32,
    pub train_accuracy: f64,
    pub train_nmi: f64,
    pub test_accuracy: Option<f64>,
    pub test_nmi: Option<f64>,
    /// Clustering wall time of the best restart (seconds, excludes
    /// dataset generation). `None` only if no restart produced a timing
    /// — `restarts >= 1` is validated at build, so a fitted report
    /// always carries `Some`; the type stays honest instead of smuggling
    /// `f64::MAX` through an empty fold.
    pub seconds: Option<f64>,
    /// Per-restart clustering times.
    pub restart_seconds: Vec<f64>,
    pub best_cost: f64,
    /// Engine provenance, including any fallback reason.
    pub engine: EngineReport,
    pub result: MiniBatchResult,
}

impl RunReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("c", Json::num(self.c_used as f64)),
            ("gamma", Json::num(self.gamma as f64)),
            ("train_accuracy", Json::num(self.train_accuracy)),
            ("train_nmi", Json::num(self.train_nmi)),
            (
                "test_accuracy",
                self.test_accuracy.map(Json::num).unwrap_or(Json::Null),
            ),
            ("test_nmi", self.test_nmi.map(Json::num).unwrap_or(Json::Null)),
            (
                "seconds",
                self.seconds.map(Json::num).unwrap_or(Json::Null),
            ),
            ("best_cost", Json::num(self.best_cost)),
            ("engine", self.engine.to_json()),
            (
                "outer_iterations",
                Json::num(self.result.history.len() as f64),
            ),
            (
                "inner_iterations",
                Json::num(
                    self.result
                        .history
                        .iter()
                        .map(|h| h.inner_iterations)
                        .sum::<usize>() as f64,
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_report_json_reflects_fallback() {
        let direct = EngineReport {
            requested: "pjrt".into(),
            used: "pjrt".into(),
            fallback: None,
        };
        let j = direct.to_json();
        assert_eq!(j.get("used").and_then(|v| v.as_str()), Some("pjrt"));
        assert_eq!(j.get("fallback"), Some(&Json::Null));

        let degraded = EngineReport {
            requested: "pjrt".into(),
            used: "native".into(),
            fallback: Some("no rbf artifact for d=33".into()),
        };
        let j = degraded.to_json();
        assert_eq!(j.get("used").and_then(|v| v.as_str()), Some("native"));
        assert!(j
            .get("fallback")
            .and_then(|v| v.as_str())
            .unwrap()
            .contains("d=33"));
    }
}
