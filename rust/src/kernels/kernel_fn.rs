//! Kernel functions on vector-space samples.
use std::str::FromStr;

/// A Mercer kernel on `R^d`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum KernelFn {
    /// `<x, y>`
    Linear,
    /// `exp(-gamma ||x - y||^2)`; the paper parameterizes by sigma with
    /// `gamma = 1 / (2 sigma^2)` and uses `sigma = 4 d_max` to mimic
    /// linear behaviour.
    Rbf { gamma: f32 },
    /// `(<x, y> + c)^degree`
    Poly { degree: u32, c: f32 },
}

impl KernelFn {
    /// RBF from the paper's sigma convention.
    pub fn rbf_from_sigma(sigma: f32) -> KernelFn {
        KernelFn::Rbf { gamma: 1.0 / (2.0 * sigma * sigma) }
    }

    /// Evaluate on a pair of samples.
    pub fn eval(&self, a: &[f32], b: &[f32]) -> f32 {
        match *self {
            KernelFn::Linear => dot(a, b),
            KernelFn::Rbf { gamma } => {
                let d2: f32 = a
                    .iter()
                    .zip(b)
                    .map(|(x, y)| (x - y) * (x - y))
                    .sum();
                (-gamma * d2).exp()
            }
            KernelFn::Poly { degree, c } => (dot(a, b) + c).powi(degree as i32),
        }
    }

    /// Evaluate from a precomputed squared distance and dot product —
    /// the blocked path computes those in bulk.
    pub fn from_parts(&self, d2: f32, dot: f32) -> f32 {
        match *self {
            KernelFn::Linear => dot,
            KernelFn::Rbf { gamma } => (-gamma * d2).exp(),
            KernelFn::Poly { degree, c } => (dot + c).powi(degree as i32),
        }
    }

    /// True if the blocked evaluator needs squared distances (RBF) rather
    /// than dot products.
    pub fn needs_d2(&self) -> bool {
        matches!(self, KernelFn::Rbf { .. })
    }

    /// RBF gamma if applicable (PJRT artifacts take gamma as an operand).
    pub fn gamma(&self) -> Option<f32> {
        match *self {
            KernelFn::Rbf { gamma } => Some(gamma),
            _ => None,
        }
    }
}

fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// The vectorized-exponential polynomial — the **single shared
/// definition** every SIMD tier's fused RBF epilogue implements.
///
/// Classic `exp_ps`-style range reduction (Cephes lineage): clamp the
/// argument, split `x = k·ln2 + r` with a two-step Cody–Waite `ln2` so
/// `r ∈ [−ln2/2, ln2/2]` stays exact, evaluate a degree-5 polynomial on
/// `r`, and scale by `2^k` assembled directly in the exponent field.
/// Accuracy on the RBF domain (`x = −γ·d² ≤ 0` down to the clamp) is
/// ~2 ULP, property-bounded at ≤4 ULP / ≤1e-6 absolute in
/// `tests/integration_simd.rs`. Arguments below the clamp flush to the
/// clamp value and, once `2^k` underflows the exponent field (`k <
/// −126`), to zero — an absolute error below `1.2e-38`, far inside the
/// bound (`f32::exp` would return a subnormal there).
///
/// Every operation here is a plain IEEE mul/add/sub — **no FMA, no
/// `mul_add`** — and the vector implementations in
/// `kernels::microkernel` mirror the exact operation order lane-wise.
/// That makes [`exp_approx`](vexp::exp_approx) a *bit-equal* scalar
/// emulation of all of them: remainder/tail columns that fall off the
/// 8-lane panels, and the pure-scalar tier, produce identical bits to
/// the vector lanes, which is what keeps row results independent of how
/// columns split into full panels and tails.
pub mod vexp {
    /// Upper clamp: just below `ln(f32::MAX)`.
    pub const EXP_HI: f32 = 88.376_26;
    /// Lower clamp: symmetric; beyond it results flush toward zero.
    pub const EXP_LO: f32 = -88.376_26;
    /// `log2(e)` for the `k = round(x / ln 2)` split.
    pub const LOG2EF: f32 = 1.442_695;
    /// High part of `ln 2` (exact in 11 bits: `0.693359375`).
    pub const LN2_HI: f32 = 0.693_359_4;
    /// Low (correction) part of `ln 2`.
    pub const LN2_LO: f32 = -2.121_944_4e-4;
    /// Degree-5 minimax coefficients for `(e^r − 1 − r) / r²`, Horner
    /// order from highest degree down.
    pub const P0: f32 = 1.987_569_1e-4;
    pub const P1: f32 = 1.398_199_9e-3;
    pub const P2: f32 = 8.333_452e-3;
    pub const P3: f32 = 4.166_579_6e-2;
    pub const P4: f32 = 1.666_666_5e-1;
    pub const P5: f32 = 0.5;

    /// Scalar reference evaluation of the shared polynomial. Bit-equal
    /// to one lane of every tier's vector implementation for the same
    /// input (the property the epilogue's tail handling relies on).
    #[inline]
    pub fn exp_approx(x: f32) -> f32 {
        let x = x.min(EXP_HI).max(EXP_LO);
        // k = floor(x * log2(e) + 0.5): round-to-nearest via floor, the
        // same emulation the SSE2 lane code uses
        let fx = (x * LOG2EF + 0.5).floor();
        // r = x - k*ln2, two-step so the subtraction is nearly exact
        let r = x - fx * LN2_HI;
        let r = r - fx * LN2_LO;
        let mut y = P0;
        y = y * r + P1;
        y = y * r + P2;
        y = y * r + P3;
        y = y * r + P4;
        y = y * r + P5;
        let z = r * r;
        y = y * z + r;
        y += 1.0;
        // 2^k assembled in the exponent field; k ∈ [-127, 127] after the
        // clamp, and k = -127 gives a zero exponent word (flush to zero)
        let k = fx as i32;
        let pow2k = f32::from_bits(((k + 127) as u32) << 23);
        y * pow2k
    }
}

impl FromStr for KernelFn {
    type Err = String;

    /// Parse "linear", "rbf:<gamma>", "rbf-sigma:<sigma>", or
    /// "poly:<degree>:<c>".
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let parts: Vec<&str> = s.split(':').collect();
        match parts.as_slice() {
            ["linear"] => Ok(KernelFn::Linear),
            ["rbf", g] => g
                .parse()
                .map(|gamma| KernelFn::Rbf { gamma })
                .map_err(|_| format!("bad gamma '{g}'")),
            ["rbf-sigma", s] => s
                .parse()
                .map(KernelFn::rbf_from_sigma)
                .map_err(|_| format!("bad sigma '{s}'")),
            ["poly", d, c] => {
                let degree = d.parse().map_err(|_| format!("bad degree '{d}'"))?;
                let c = c.parse().map_err(|_| format!("bad c '{c}'"))?;
                Ok(KernelFn::Poly { degree, c })
            }
            _ => Err(format!("unknown kernel '{s}'")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_is_dot() {
        let k = KernelFn::Linear;
        assert_eq!(k.eval(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
    }

    #[test]
    fn rbf_bounds_and_identity() {
        let k = KernelFn::Rbf { gamma: 0.5 };
        assert!((k.eval(&[1.0, 2.0], &[1.0, 2.0]) - 1.0).abs() < 1e-7);
        let v = k.eval(&[0.0, 0.0], &[10.0, 10.0]);
        assert!(v > 0.0 && v < 1e-6);
    }

    #[test]
    fn rbf_sigma_convention() {
        let k = KernelFn::rbf_from_sigma(2.0);
        // gamma = 1/8 -> at d2 = 8, k = e^-1
        let v = k.eval(&[0.0], &[8.0f32.sqrt()]);
        assert!((v - (-1.0f32).exp()).abs() < 1e-5);
    }

    #[test]
    fn poly_matches_manual() {
        let k = KernelFn::Poly { degree: 2, c: 1.0 };
        // (1*3 + 2*4 + 1)^2 = 144
        assert_eq!(k.eval(&[1.0, 2.0], &[3.0, 4.0]), 144.0);
    }

    #[test]
    fn from_parts_consistent_with_eval() {
        let a = [0.5f32, -1.0, 2.0];
        let b = [1.5f32, 0.0, -0.5];
        let d2: f32 = a.iter().zip(&b).map(|(x, y)| (x - y) * (x - y)).sum();
        let dp: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        for k in [
            KernelFn::Linear,
            KernelFn::Rbf { gamma: 0.3 },
            KernelFn::Poly { degree: 3, c: 0.5 },
        ] {
            assert!((k.eval(&a, &b) - k.from_parts(d2, dp)).abs() < 1e-5);
        }
    }

    #[test]
    fn vexp_matches_libm_on_rbf_domain() {
        // the RBF argument is -gamma*d2 <= 0; sweep it densely down to
        // the clamp and check the polynomial against f32::exp
        let mut x = 0.0f32;
        while x > -87.0 {
            let got = vexp::exp_approx(x);
            let want = x.exp();
            let abs = (got - want).abs();
            let rel = abs / want.max(f32::MIN_POSITIVE);
            assert!(
                abs <= 1e-6 || rel <= 4.0 * f32::EPSILON,
                "exp_approx({x}) = {got}, libm = {want}"
            );
            x -= 0.0173; // irrational-ish step to avoid hitting only round args
        }
    }

    #[test]
    fn vexp_identity_and_edges() {
        // exp(0) must be exactly 1 (the Gram diagonal contract) and -0.0
        // must agree with it bit-for-bit
        assert_eq!(vexp::exp_approx(0.0).to_bits(), 1.0f32.to_bits());
        assert_eq!(vexp::exp_approx(-0.0).to_bits(), 1.0f32.to_bits());
        // tiny arguments stay within a ULP of 1
        assert!((vexp::exp_approx(-1e-20) - 1.0).abs() <= f32::EPSILON);
        // beyond the clamp: flush toward zero, never negative, and the
        // absolute error vs the true (subnormal) value stays tiny
        for x in [-88.0f32, -88.376_26, -100.0, -1.0e4, -1.0e30, f32::NEG_INFINITY] {
            let got = vexp::exp_approx(x);
            assert!(
                (0.0..=1.2e-38).contains(&got),
                "exp_approx({x}) = {got} must flush toward zero"
            );
        }
        // upper clamp (unused by RBF but part of the contract): finite
        let hi = vexp::exp_approx(1.0e4);
        assert!(hi.is_finite() && (hi - vexp::EXP_HI.exp()).abs() / vexp::EXP_HI.exp() < 1e-5);
    }

    #[test]
    fn parse_roundtrip() {
        assert_eq!("linear".parse::<KernelFn>().unwrap(), KernelFn::Linear);
        assert_eq!(
            "rbf:0.25".parse::<KernelFn>().unwrap(),
            KernelFn::Rbf { gamma: 0.25 }
        );
        assert_eq!(
            "poly:2:1.0".parse::<KernelFn>().unwrap(),
            KernelFn::Poly { degree: 2, c: 1.0 }
        );
        assert!("rbf".parse::<KernelFn>().is_err());
        assert!("nope:1".parse::<KernelFn>().is_err());
    }
}
