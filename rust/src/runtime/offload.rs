//! Producer-consumer offload pipeline (paper Fig.3).
//!
//! The accelerator (PJRT device thread, or the native evaluator standing
//! in for it) computes the kernel blocks of mini-batch i+1 while the host
//! threads run the inner GD loop on mini-batch i. [`Prefetcher`] is the
//! generic machinery: a bounded queue of depth >= 1 between a producer
//! thread and the consuming coordinator loop, with stall accounting on
//! both sides so the overlap efficiency is measurable (EXPERIMENTS.md
//! §Perf reports it).
//!
//! The clustering path itself now runs on the memory-budgeted tile
//! pipeline (`kernels::tiles`), which generalizes this scheme to a
//! worker pool over budget-sized tiles; `Prefetcher` remains the
//! standalone device-queue utility (and the Fig.3 reference shape).
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use crate::util::stats::Timer;

/// Producer/consumer stall accounting.
#[derive(Clone, Debug, Default)]
pub struct OffloadStats {
    /// Seconds the producer spent computing items.
    pub producer_busy_s: f64,
    /// Seconds the consumer waited on an empty queue.
    pub consumer_wait_s: f64,
    /// Items produced.
    pub items: usize,
}

impl OffloadStats {
    /// Fraction of producer work hidden behind consumer compute:
    /// 1 - wait/busy (clamped), the Fig.3 overlap figure of merit.
    pub fn overlap_efficiency(&self) -> f64 {
        if self.producer_busy_s <= 0.0 {
            return 1.0;
        }
        (1.0 - self.consumer_wait_s / self.producer_busy_s).clamp(0.0, 1.0)
    }
}

/// Prefetching pipeline over an indexed producer function.
pub struct Prefetcher<T: Send + 'static> {
    rx: mpsc::Receiver<T>,
    stats: Arc<Mutex<OffloadStats>>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl<T: Send + 'static> Prefetcher<T> {
    /// Spawn a producer computing `produce(i)` for `i in 0..total`,
    /// keeping at most `depth` finished items queued. `depth = 1`
    /// reproduces the paper's scheme (device works exactly one mini-batch
    /// ahead).
    pub fn spawn<F>(total: usize, depth: usize, produce: F) -> Prefetcher<T>
    where
        F: Fn(usize) -> T + Send + 'static,
    {
        assert!(depth >= 1);
        let (tx, rx) = mpsc::sync_channel(depth);
        let stats = Arc::new(Mutex::new(OffloadStats::default()));
        let pstats = stats.clone();
        let join = std::thread::Builder::new()
            .name("offload-producer".into())
            .spawn(move || {
                for i in 0..total {
                    let t = Timer::start();
                    let item = produce(i);
                    {
                        let mut s = pstats.lock().unwrap();
                        s.producer_busy_s += t.elapsed_s();
                        s.items += 1;
                    }
                    if tx.send(item).is_err() {
                        break; // consumer dropped early
                    }
                }
            })
            .expect("spawn offload producer");
        Prefetcher { rx, stats, join: Some(join) }
    }

    /// Blocking fetch of the next item (None when the producer finished).
    pub fn next(&mut self) -> Option<T> {
        let t = Timer::start();
        let item = self.rx.recv().ok();
        self.stats.lock().unwrap().consumer_wait_s += t.elapsed_s();
        item
    }

    pub fn stats(&self) -> OffloadStats {
        self.stats.lock().unwrap().clone()
    }
}

impl<T: Send + 'static> Drop for Prefetcher<T> {
    fn drop(&mut self) {
        // drain so the producer unblocks, then join
        while self.rx.try_recv().is_ok() {}
        if let Some(join) = self.join.take() {
            // producer exits on send error once rx is dropped; joining
            // here after drain avoids leaks in the normal path
            drop(std::mem::replace(&mut self.rx, mpsc::channel().1));
            let _ = join.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn yields_all_items_in_order() {
        let mut p = Prefetcher::spawn(10, 1, |i| i * i);
        let mut got = Vec::new();
        while let Some(v) = p.next() {
            got.push(v);
        }
        assert_eq!(got, (0..10).map(|i| i * i).collect::<Vec<_>>());
        assert_eq!(p.stats().items, 10);
    }

    #[test]
    fn overlaps_production_with_consumption() {
        // producer takes 10ms/item, consumer 10ms/item: with depth-1
        // prefetch the consumer should almost never wait after the first
        let mut p = Prefetcher::spawn(8, 1, |i| {
            std::thread::sleep(Duration::from_millis(10));
            i
        });
        let mut count = 0;
        while let Some(_v) = p.next() {
            std::thread::sleep(Duration::from_millis(10));
            count += 1;
        }
        assert_eq!(count, 8);
        let stats = p.stats();
        // waits ≈ first item only (~10ms) vs busy ≈ 80ms
        assert!(
            stats.overlap_efficiency() > 0.5,
            "overlap {} (busy {}, wait {})",
            stats.overlap_efficiency(),
            stats.producer_busy_s,
            stats.consumer_wait_s
        );
    }

    #[test]
    fn early_drop_does_not_hang() {
        let mut p = Prefetcher::spawn(1000, 2, |i| vec![0u8; 1024 + i]);
        let _ = p.next();
        drop(p); // must not deadlock on the blocked producer
    }

    #[test]
    fn zero_items() {
        let mut p: Prefetcher<usize> = Prefetcher::spawn(0, 1, |i| i);
        assert_eq!(p.next(), None);
    }

    #[test]
    fn depth_allows_run_ahead() {
        let mut p = Prefetcher::spawn(4, 4, |i| i);
        // give the producer time to fill the whole queue
        std::thread::sleep(Duration::from_millis(50));
        assert_eq!(p.stats().items, 4);
        assert_eq!(p.next(), Some(0));
    }
}
