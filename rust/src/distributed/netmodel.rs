//! Alpha-beta network cost model for the strong-scaling study (Fig.6).
//!
//! The paper measured on IBM BG/Q (5D torus, proprietary interconnect)
//! and IBM NeXtScale (InfiniBand 4x QDR). Neither machine is available
//! here, so per-node *compute* is measured on this host and *network*
//! time comes from the standard alpha-beta model with per-topology
//! parameters (DESIGN.md §3). What must survive the substitution is the
//! scaling *shape*: near-ideal mid-range, Amdahl flattening when the
//! serial fraction and collective latency dominate.
use std::str::FromStr;

/// Interconnect topology with alpha-beta parameters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Topology {
    /// IBM BG/Q: 5D torus. Per-link latency is low and the torus gives
    /// log-ish collective depth with high per-link bandwidth (2 GB/s).
    BgqTorus5D,
    /// InfiniBand 4x QDR fat tree (NeXtScale): 32 Gbit/s, ~1.3 us MPI
    /// latency, tree collectives.
    InfinibandQdr,
}

impl Topology {
    /// Per-hop software+wire latency (seconds).
    pub fn alpha(&self) -> f64 {
        match self {
            Topology::BgqTorus5D => 2.5e-6,
            Topology::InfinibandQdr => 1.3e-6,
        }
    }

    /// Per-byte transfer time (seconds/byte).
    pub fn beta(&self) -> f64 {
        match self {
            Topology::BgqTorus5D => 1.0 / 2.0e9,
            Topology::InfinibandQdr => 1.0 / 4.0e9, // 32 Gb/s
        }
    }

    /// Collective tree depth for `p` nodes: the 5D torus has a slightly
    /// higher effective depth constant than a fat-tree.
    pub fn depth(&self, p: usize) -> f64 {
        let lg = (p.max(1) as f64).log2().ceil().max(1.0);
        match self {
            Topology::BgqTorus5D => 1.25 * lg,
            Topology::InfinibandQdr => lg,
        }
    }
}

impl FromStr for Topology {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "bgq" => Ok(Topology::BgqTorus5D),
            "infiniband" | "ib" => Ok(Topology::InfinibandQdr),
            other => Err(format!("unknown topology '{other}' (bgq|infiniband)")),
        }
    }
}

/// Cost model over a topology.
#[derive(Clone, Copy, Debug)]
pub struct NetModel {
    pub topology: Topology,
}

impl NetModel {
    pub fn new(topology: Topology) -> NetModel {
        NetModel { topology }
    }

    /// Allreduce of `bytes` across `p` nodes (tree: up + down).
    pub fn allreduce(&self, p: usize, bytes: usize) -> f64 {
        if p <= 1 {
            return 0.0;
        }
        let t = self.topology;
        2.0 * t.depth(p) * (t.alpha() + bytes as f64 * t.beta())
    }

    /// Allgather where each node contributes `bytes_per_node` (ring).
    pub fn allgather(&self, p: usize, bytes_per_node: usize) -> f64 {
        if p <= 1 {
            return 0.0;
        }
        let t = self.topology;
        (p - 1) as f64 * (t.alpha() + bytes_per_node as f64 * t.beta())
    }

    /// Broadcast of `bytes` (tree).
    pub fn broadcast(&self, p: usize, bytes: usize) -> f64 {
        if p <= 1 {
            return 0.0;
        }
        let t = self.topology;
        t.depth(p) * (t.alpha() + bytes as f64 * t.beta())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_node_free() {
        let m = NetModel::new(Topology::BgqTorus5D);
        assert_eq!(m.allreduce(1, 1024), 0.0);
        assert_eq!(m.allgather(1, 1024), 0.0);
    }

    #[test]
    fn allreduce_grows_logarithmically() {
        let m = NetModel::new(Topology::InfinibandQdr);
        let t16 = m.allreduce(16, 128);
        let t256 = m.allreduce(256, 128);
        // log2(256)/log2(16) = 2, so roughly doubles
        assert!(t256 > t16 * 1.5 && t256 < t16 * 3.0, "{t16} {t256}");
    }

    #[test]
    fn allgather_linear_in_p() {
        let m = NetModel::new(Topology::InfinibandQdr);
        let t4 = m.allgather(4, 1000);
        let t8 = m.allgather(8, 1000);
        assert!((t8 / t4 - 7.0 / 3.0).abs() < 0.01);
    }

    #[test]
    fn bandwidth_term_dominates_large_messages() {
        let m = NetModel::new(Topology::BgqTorus5D);
        let small = m.allreduce(64, 4);
        let large = m.allreduce(64, 4 << 20);
        assert!(large > small * 100.0);
    }

    #[test]
    fn topologies_differ() {
        let bgq = NetModel::new(Topology::BgqTorus5D);
        let ib = NetModel::new(Topology::InfinibandQdr);
        assert!(bgq.allreduce(128, 64) != ib.allreduce(128, 64));
    }

    #[test]
    fn parse() {
        assert_eq!("bgq".parse::<Topology>().unwrap(), Topology::BgqTorus5D);
        assert_eq!("ib".parse::<Topology>().unwrap(), Topology::InfinibandQdr);
        assert!("x".parse::<Topology>().is_err());
    }
}
