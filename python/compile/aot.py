# AOT export: lower every L2 graph variant to HLO *text* under artifacts/,
# plus a manifest.json the Rust runtime uses to pick executables by shape.
#
# HLO text (NOT .serialize()): jax >= 0.5 emits HloModuleProto with 64-bit
# instruction ids which xla_extension 0.5.1 (the version behind the `xla`
# crate) rejects; the text parser reassigns ids and round-trips cleanly.
# See /opt/xla-example/gen_hlo.py.
#
# Golden vectors: for a few variants we also dump deterministic input /
# expected-output tensors (computed with the pure-jnp oracle in
# kernels/ref.py, *not* the Pallas path) as little-endian binaries, so the
# Rust integration tests can assert end-to-end PJRT numerics.
import argparse
import json
import os

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import ref

F32 = jnp.float32

# ----------------------------------------------------------------------
# Variant table. Shapes are chosen so that:
#   * matrix dims are multiples of the 128-lane MXU tile,
#   * C = 32 covers every experiment in the paper (C in {4, 10, 20}; Rust
#     pads the one-hot with zero columns + valid mask),
#   * L in {256, 1024} covers single-chunk landmark sets; larger L uses the
#     chunk-accumulating f_partial/argmin pair.
RBF_DIMS = [2, 64, 256, 784]
RBF_TILE = 256
ASSIGN_N = 1024
ASSIGN_LS = [256, 1024]
ASSIGN_C = 32


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def to_hlo_text(fn, specs):
    lowered = jax.jit(fn).lower(*specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def variants():
    """Yield (name, fn, input_specs, params) for every artifact."""
    for d in RBF_DIMS:
        yield (
            f"rbf_t{RBF_TILE}_d{d}",
            model.kernel_block_rbf,
            [spec((RBF_TILE, d)), spec((RBF_TILE, d)), spec((1, 1))],
            {"kind": "rbf", "tile_m": RBF_TILE, "tile_n": RBF_TILE, "d": d},
        )
    yield (
        f"linear_t{RBF_TILE}_d784",
        model.kernel_block_linear,
        [spec((RBF_TILE, 784)), spec((RBF_TILE, 784))],
        {"kind": "linear", "tile_m": RBF_TILE, "tile_n": RBF_TILE, "d": 784},
    )
    for l in ASSIGN_LS:
        n, c = ASSIGN_N, ASSIGN_C
        yield (
            f"inner_n{n}_l{l}_c{c}",
            model.inner_iteration,
            [spec((n, l)), spec((l, l)), spec((l, c)), spec((1, c)), spec((1, c))],
            {"kind": "inner", "n": n, "l": l, "c": c},
        )
        yield (
            f"assign_n{n}_l{l}_c{c}",
            model.assign_step,
            [spec((n, l)), spec((l, c)), spec((1, c)), spec((1, c)), spec((1, c))],
            {"kind": "assign", "n": n, "l": l, "c": c},
        )
        yield (
            f"gstep_l{l}_c{c}",
            model.g_step,
            [spec((l, l)), spec((l, c)), spec((1, c))],
            {"kind": "gstep", "l": l, "c": c},
        )
    yield (
        f"fpartial_n{ASSIGN_N}_l256_c{ASSIGN_C}",
        model.f_partial,
        [spec((ASSIGN_N, 256)), spec((256, ASSIGN_C))],
        {"kind": "fpartial", "n": ASSIGN_N, "l": 256, "c": ASSIGN_C},
    )
    yield (
        f"argmin_n{ASSIGN_N}_c{ASSIGN_C}",
        model.argmin_step,
        [
            spec((ASSIGN_N, ASSIGN_C)),
            spec((1, ASSIGN_C)),
            spec((1, ASSIGN_C)),
            spec((1, ASSIGN_C)),
        ],
        {"kind": "argmin", "n": ASSIGN_N, "c": ASSIGN_C},
    )


def shape_entry(s):
    dt = {jnp.float32.dtype: "f32", jnp.int32.dtype: "i32"}[jnp.dtype(s.dtype)]
    return [dt, list(s.shape)]


def dump_bin(path, arr):
    np.asarray(arr).astype(
        np.int32 if arr.dtype in (jnp.int32, np.int32) else np.float32
    ).tofile(path)


def golden_rbf(outdir, d):
    """Deterministic input/expected-output pair for the rbf_t256_d{d} artifact."""
    rng = np.random.default_rng(1234 + d)
    x = rng.standard_normal((RBF_TILE, d)).astype(np.float32)
    y = rng.standard_normal((RBF_TILE, d)).astype(np.float32)
    gamma = np.array([[0.05]], dtype=np.float32)
    k = np.asarray(ref.rbf(jnp.asarray(x), jnp.asarray(y), 0.05))
    base = os.path.join(outdir, "golden", f"rbf_t{RBF_TILE}_d{d}")
    dump_bin(base + ".x.bin", x)
    dump_bin(base + ".y.bin", y)
    dump_bin(base + ".gamma.bin", gamma)
    dump_bin(base + ".out.bin", k)
    return {
        "name": f"rbf_t{RBF_TILE}_d{d}",
        "inputs": [
            f"golden/rbf_t{RBF_TILE}_d{d}.x.bin",
            f"golden/rbf_t{RBF_TILE}_d{d}.y.bin",
            f"golden/rbf_t{RBF_TILE}_d{d}.gamma.bin",
        ],
        "outputs": [f"golden/rbf_t{RBF_TILE}_d{d}.out.bin"],
        "atol": 2e-5,
    }


def golden_inner(outdir):
    """Golden pair for inner_n1024_l256_c32 with realistic cluster structure."""
    n, l, c_real, c = ASSIGN_N, 256, 10, ASSIGN_C
    rng = np.random.default_rng(99)
    centers = rng.standard_normal((c_real, 16)) * 3.0
    xs = centers[rng.integers(0, c_real, n)] + rng.standard_normal((n, 16))
    lm = centers[rng.integers(0, c_real, l)] + rng.standard_normal((l, 16))
    gamma = 0.05
    k_nl = np.asarray(ref.rbf(jnp.asarray(xs, F32), jnp.asarray(lm, F32), gamma))
    k_ll = np.asarray(ref.rbf(jnp.asarray(lm, F32), jnp.asarray(lm, F32), gamma))
    labels_l = rng.integers(0, c_real, l).astype(np.int32)
    m = np.asarray(ref.onehot(jnp.asarray(labels_l), c))
    inv = np.asarray(ref.inv_sizes(jnp.asarray(labels_l), c))[None, :]
    valid = (np.asarray(ref.sizes(jnp.asarray(labels_l), c)) > 0).astype(
        np.float32
    )[None, :]
    g = np.asarray(
        ref.g_compactness(jnp.asarray(k_ll), jnp.asarray(m), jnp.asarray(inv[0]))
    )[None, :]
    labels = np.asarray(
        ref.assign(
            jnp.asarray(k_nl),
            jnp.asarray(m),
            jnp.asarray(inv[0]),
            jnp.asarray(g[0]),
            jnp.asarray(valid[0]),
        )
    )[:, None]
    base = os.path.join(outdir, "golden", "inner_n1024_l256_c32")
    for suffix, arr in [
        (".knl.bin", k_nl),
        (".kll.bin", k_ll),
        (".m.bin", m),
        (".inv.bin", inv),
        (".valid.bin", valid),
        (".labels.bin", labels.astype(np.int32)),
        (".g.bin", g),
    ]:
        dump_bin(base + suffix, arr)
    rel = "golden/inner_n1024_l256_c32"
    return {
        "name": "inner_n1024_l256_c32",
        "inputs": [
            f"{rel}.knl.bin",
            f"{rel}.kll.bin",
            f"{rel}.m.bin",
            f"{rel}.inv.bin",
            f"{rel}.valid.bin",
        ],
        "outputs": [f"{rel}.labels.bin", f"{rel}.g.bin"],
        "atol": 2e-5,
    }


def main():
    p = argparse.ArgumentParser(description="AOT-lower dkkm graphs to HLO text")
    p.add_argument("--outdir", default="../artifacts")
    p.add_argument("--skip-golden", action="store_true")
    args = p.parse_args()
    os.makedirs(args.outdir, exist_ok=True)
    os.makedirs(os.path.join(args.outdir, "golden"), exist_ok=True)

    entries = []
    for name, fn, specs, params in variants():
        text = to_hlo_text(fn, specs)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.outdir, fname), "w") as f:
            f.write(text)
        out_specs = jax.eval_shape(fn, *specs)
        entries.append(
            {
                "name": name,
                "file": fname,
                "inputs": [shape_entry(s) for s in specs],
                "outputs": [shape_entry(s) for s in out_specs],
                "params": params,
            }
        )
        print(f"lowered {name}: {len(text)} chars")

    golden = []
    if not args.skip_golden:
        golden.append(golden_rbf(args.outdir, 64))
        golden.append(golden_inner(args.outdir))

    manifest = {"version": 1, "entries": entries, "golden": golden}
    with open(os.path.join(args.outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote manifest with {len(entries)} entries, {len(golden)} golden sets")


if __name__ == "__main__":
    main()
