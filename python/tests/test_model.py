# L2 graph tests: the composed inner iteration + a miniature kernel
# k-means driver written against ref.py, checking the fixed point / cost
# monotonicity properties the coordinator relies on.
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile import model
from compile.kernels import ref


def blobs(rng, n, c, d=8, spread=4.0):
    centers = rng.standard_normal((c, d)) * spread
    labels = rng.integers(0, c, n)
    return (
        jnp.asarray(centers[labels] + rng.standard_normal((n, d)), jnp.float32),
        labels,
    )


class TestInnerIteration:
    def test_matches_ref_pipeline(self):
        rng = np.random.default_rng(0)
        xs, _ = blobs(rng, 1024, 10)
        lm = xs[:256]
        labels_l = jnp.asarray(rng.integers(0, 10, 256), jnp.int32)
        knl = ref.rbf(xs, lm, 0.1)
        kll = ref.rbf(lm, lm, 0.1)
        m = ref.onehot(labels_l, 32)
        inv = ref.inv_sizes(labels_l, 32)
        valid = (ref.sizes(labels_l, 32) > 0).astype(jnp.float32)
        labels, g = model.inner_iteration(
            knl, kll, m, inv[None, :], valid[None, :]
        )
        want = ref.kernel_kmeans_iteration(knl, kll, labels_l, 32)
        assert np.array_equal(np.asarray(labels)[:, 0], np.asarray(want))
        assert_allclose(
            np.asarray(g)[0],
            np.asarray(ref.g_compactness(kll, m, inv)),
            atol=2e-5,
        )

    def test_well_separated_blobs_reach_fixed_point(self):
        """On trivially separable data, iterating to convergence recovers
        the generating partition (up to label permutation)."""
        rng = np.random.default_rng(1)
        xs, true = blobs(rng, 512, 4, d=2, spread=50.0)
        labels = jnp.asarray(rng.integers(0, 4, 512), jnp.int32)
        k = ref.rbf(xs, xs, 0.02)
        for _ in range(30):
            new = ref.kernel_kmeans_iteration(k, k, labels, 32)
            if np.array_equal(np.asarray(new), np.asarray(labels)):
                break
            labels = new
        # each true blob maps to exactly one predicted cluster
        for t in range(4):
            got = np.asarray(labels)[true == t]
            assert len(set(got.tolist())) == 1

    def test_cost_nonincreasing_full_batch(self):
        """Eq.4 iterations never increase Omega (Bottou-Bengio property)."""
        rng = np.random.default_rng(2)
        xs, _ = blobs(rng, 384, 6, d=4)
        k = ref.rbf(xs, xs, 0.1)
        labels = jnp.asarray(rng.integers(0, 6, 384), jnp.int32)
        prev = float(ref.cost(k, labels, 32))
        for _ in range(15):
            labels = ref.kernel_kmeans_iteration(k, k, labels, 32)
            cur = float(ref.cost(k, labels, 32))
            assert cur <= prev + 1e-3
            prev = cur

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_fixed_point_is_stable(self, seed):
        """Once labels stop changing they stay fixed (self-consistency)."""
        rng = np.random.default_rng(seed)
        xs, _ = blobs(rng, 256, 5, d=3)
        k = ref.rbf(xs, xs, 0.1)
        labels = jnp.asarray(rng.integers(0, 5, 256), jnp.int32)
        for _ in range(40):
            new = ref.kernel_kmeans_iteration(k, k, labels, 32)
            if np.array_equal(np.asarray(new), np.asarray(labels)):
                break
            labels = new
        again = ref.kernel_kmeans_iteration(k, k, labels, 32)
        assert np.array_equal(np.asarray(again), np.asarray(labels))


class TestCost:
    def test_cost_is_within_cluster_scatter(self):
        """Omega from the kernel trick == explicit feature-space scatter
        for the linear kernel."""
        rng = np.random.default_rng(3)
        xs, _ = blobs(rng, 200, 3, d=4)
        # pad to nothing special; cost works on any square K
        k = xs @ xs.T
        labels = jnp.asarray(rng.integers(0, 3, 200), jnp.int32)
        omega = float(ref.cost(k, labels, 8))
        explicit = 0.0
        xs_np = np.asarray(xs)
        for j in range(3):
            pts = xs_np[np.asarray(labels) == j]
            if len(pts):
                explicit += ((pts - pts.mean(0)) ** 2).sum()
        assert_allclose(omega, explicit, rtol=1e-3)

    def test_perfect_clustering_cost_lower_than_random(self):
        rng = np.random.default_rng(4)
        xs, true = blobs(rng, 300, 3, d=4, spread=20.0)
        k = ref.rbf(xs, xs, 0.05)
        rand_labels = jnp.asarray(rng.integers(0, 3, 300), jnp.int32)
        assert float(ref.cost(k, jnp.asarray(true, jnp.int32), 8)) < float(
            ref.cost(k, rand_labels, 8)
        )
