//! Minimal JSON parser + writer (serde substitute).
//!
//! Used for the AOT artifact manifest (`artifacts/manifest.json`), run
//! configuration files, and machine-readable bench/experiment reports.
//! Supports the full JSON grammar minus exotic number forms; numbers are
//! kept as `f64` (ints round-trip exactly up to 2^53, far beyond any shape
//! or count we store).
use std::collections::BTreeMap;
use std::fmt;

use super::error::{Error, Result};

/// A JSON value. Objects preserve deterministic (sorted) key order via
/// `BTreeMap`, which keeps emitted reports diffable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Array element access.
    pub fn at(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 {
                Some(x as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Required-field helpers that produce good error messages.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| Error::Config(format!("missing JSON field '{key}'")))
    }

    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| Error::Config(format!("field '{key}' is not a string")))
    }

    pub fn req_usize(&self, key: &str) -> Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| Error::Config(format!("field '{key}' is not an integer")))
    }

    /// Builder helpers for report emission.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

impl fmt::Display for Json {
    /// Compact serialization (valid JSON).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{item}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Config(format!("JSON parse error at byte {}: {msg}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, value: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(value)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(b'-' | b'0'..=b'9') => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self
                                .bump()
                                .and_then(|b| (b as char).to_digit(16))
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            code = code * 16 + d;
                        }
                        // surrogate pairs: decode if a low surrogate follows
                        let c = if (0xD800..0xDC00).contains(&code) {
                            if self.bytes[self.pos..].starts_with(b"\\u") {
                                self.pos += 2;
                                let mut low = 0u32;
                                for _ in 0..4 {
                                    let d = self
                                        .bump()
                                        .and_then(|b| (b as char).to_digit(16))
                                        .ok_or_else(|| self.err("bad \\u escape"))?;
                                    low = low * 16 + d;
                                }
                                0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00)
                            } else {
                                return Err(self.err("lone high surrogate"));
                            }
                        } else {
                            code
                        };
                        out.push(
                            char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x80 => out.push(b as char),
                Some(b) => {
                    // re-assemble multi-byte UTF-8 (input came from &str, so
                    // it is valid; find the char at pos-1)
                    let start = self.pos - 1;
                    let width = utf8_width(b);
                    self.pos = start + width;
                    let s = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(s);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid utf-8 in number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_width(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(
            Json::parse("\"hi\"").unwrap(),
            Json::Str("hi".to_string())
        );
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().at(1).unwrap().as_f64(), Some(2.0));
        assert_eq!(v.get("a").unwrap().at(2).unwrap().get("b"), Some(&Json::Null));
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn parses_escapes() {
        let v = Json::parse(r#""a\n\t\"\\Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\Aé"));
    }

    #[test]
    fn parses_surrogate_pair() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"entries":[{"file":"x.hlo.txt","inputs":[["f32",[256,64]]],"name":"rbf"}],"version":1}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.to_string(), src);
        // and re-parsing the emission is stable
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn roundtrip_unicode_and_floats() {
        let v = Json::obj(vec![
            ("name", Json::str("é😀\"quote\"")),
            ("x", Json::num(0.125)),
            ("n", Json::num(3.0)),
        ]);
        let parsed = Json::parse(&v.to_string()).unwrap();
        assert_eq!(parsed, v);
    }

    #[test]
    fn helpers() {
        let v = Json::parse(r#"{"n": 5, "s": "x"}"#).unwrap();
        assert_eq!(v.req_usize("n").unwrap(), 5);
        assert_eq!(v.req_str("s").unwrap(), "x");
        assert!(v.req("missing").is_err());
        assert!(v.req_usize("s").is_err());
    }

    #[test]
    fn whitespace_tolerant() {
        let v = Json::parse(" \n\t{ \"a\" : [ 1 , 2 ] } \r\n").unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 2);
    }
}
