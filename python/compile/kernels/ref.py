# Pure-jnp correctness oracles for every Pallas kernel and for the full
# kernel k-means update. pytest asserts allclose(kernel, ref) — this is the
# CORE correctness signal for L1, and the Rust native path is in turn tested
# against numbers exported from these functions.
import jax.numpy as jnp

BIG = jnp.float32(3.4e38)


def sq_dists(x, y):
    """Exact pairwise squared distances (no MXU re-association)."""
    diff = x[:, None, :] - y[None, :, :]
    return jnp.sum(diff * diff, axis=-1)


def rbf(x, y, gamma):
    """K[i,j] = exp(-gamma ||x_i - y_j||^2)."""
    return jnp.exp(-gamma * sq_dists(x, y))


def linear(x, y):
    """K[i,j] = <x_i, y_j>."""
    return x @ y.T


def onehot(labels, c):
    """(l,) int labels -> (l, c) f32 one-hot membership matrix."""
    return (labels[:, None] == jnp.arange(c)[None, :]).astype(jnp.float32)


def sizes(labels, c):
    """Cluster cardinalities |w_j| from labels."""
    return jnp.sum(onehot(labels, c), axis=0)


def inv_sizes(labels, c):
    """1/|w_j| with empty clusters mapped to 0 (paper's alpha=0 rule)."""
    s = sizes(labels, c)
    return jnp.where(s > 0, 1.0 / jnp.maximum(s, 1.0), 0.0)


def f_similarity(k, m, inv):
    """Cluster average similarity f_ij = inv_j sum_m K_im M_mj (Eq.6/17)."""
    return (k @ m) * inv[None, :]


def g_compactness(kll, m, inv):
    """Cluster compactness g_j = inv_j^2 M_j^T K_LL M_j (Eq.5/16)."""
    quad = jnp.einsum("mj,mn,nj->j", m, kll, m)
    return quad * inv * inv


def assign(k, m, inv, g, valid):
    """Label update u_i = argmin_j g_j - 2 f_ij over valid clusters (Eq.4)."""
    f = f_similarity(k, m, inv)
    dist = jnp.where(valid[None, :] > 0, g[None, :] - 2.0 * f, BIG)
    return jnp.argmin(dist, axis=1).astype(jnp.int32)


def kernel_kmeans_iteration(k_nl, k_ll, labels_l, c):
    """One full inner-loop iteration from landmark labels (Eq.15-17).

    k_nl: (n, l) sample-vs-landmark kernel rows; k_ll: (l, l) landmark
    block; labels_l: (l,) current landmark labels. Returns (n,) i32.
    """
    m = onehot(labels_l, c)
    inv = inv_sizes(labels_l, c)
    g = g_compactness(k_ll, m, inv)
    valid = (sizes(labels_l, c) > 0).astype(jnp.float32)
    return assign(k_nl, m, inv, g, valid)


def cost(k, labels, c):
    """Kernel k-means cost Omega(W) (Eq.1), expanded with the kernel trick:

    Omega = sum_i K_ii - sum_j |w_j| g_j  (since sum_i ||phi_i - w_ui||^2
    = sum_i K_ii - 2 sum_i f_{i,ui} + sum_j |w_j| g_j and
    sum_i f_{i,ui} = sum_j |w_j| g_j when f, g come from the same labels).
    """
    m = onehot(labels, c)
    s = jnp.sum(m, axis=0)
    inv = jnp.where(s > 0, 1.0 / jnp.maximum(s, 1.0), 0.0)
    g = g_compactness(k, m, inv)
    return jnp.trace(k) - jnp.sum(s * g)
