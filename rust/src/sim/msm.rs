//! Markov State Model estimation over clustered trajectory frames.
//!
//! The paper motivates MD clustering with "quantitively estimating
//! kinetics rates via Markov State Models" (§1): once frames are
//! clustered, the cluster-label sequence along the trajectory defines a
//! discrete jump process whose transition matrix yields relaxation
//! timescales and stationary populations. This module provides that
//! downstream analysis: transition counts at a lag time, row-stochastic
//! transition matrix (with a reversibility symmetrization option),
//! stationary distribution and implied timescales via deflated power
//! iteration.
use crate::util::error::{Error, Result};

/// A row-stochastic Markov state model.
#[derive(Clone, Debug)]
pub struct Msm {
    /// Number of states (clusters).
    pub n_states: usize,
    /// Lag time (in frames) used for counting.
    pub lag: usize,
    /// Row-stochastic transition matrix, row-major `n_states^2`.
    pub t: Vec<f64>,
    /// Raw transition counts.
    pub counts: Vec<f64>,
}

/// Count transitions `labels[t] -> labels[t + lag]`.
///
/// `breaks` marks trajectory restart points (swarm simulations — see
/// `sim::md::simulate`): pairs spanning a break are skipped so restarts
/// do not inject fake unbinding transitions.
pub fn count_transitions(
    labels: &[usize],
    n_states: usize,
    lag: usize,
    breaks: &[usize],
) -> Result<Vec<f64>> {
    if lag == 0 || lag >= labels.len() {
        return Err(Error::Config(format!(
            "lag {lag} out of range for {} frames",
            labels.len()
        )));
    }
    if let Some(&bad) = labels.iter().find(|&&u| u >= n_states) {
        return Err(Error::Config(format!("label {bad} >= n_states {n_states}")));
    }
    let mut is_break = vec![false; labels.len() + 1];
    for &b in breaks {
        if b < is_break.len() {
            is_break[b] = true;
        }
    }
    let mut counts = vec![0.0f64; n_states * n_states];
    'outer: for t in 0..labels.len() - lag {
        // skip pairs that straddle a restart
        for k in (t + 1)..=(t + lag) {
            if is_break[k] {
                continue 'outer;
            }
        }
        counts[labels[t] * n_states + labels[t + lag]] += 1.0;
    }
    Ok(counts)
}

/// Build a row-stochastic MSM from a label sequence.
///
/// `reversible` applies the standard symmetrization `C <- (C + C^T)/2`
/// before normalization (detailed-balance estimator for equilibrium
/// data), which also guarantees real eigenvalues.
pub fn estimate_msm(
    labels: &[usize],
    n_states: usize,
    lag: usize,
    breaks: &[usize],
    reversible: bool,
) -> Result<Msm> {
    let mut counts = count_transitions(labels, n_states, lag, breaks)?;
    if reversible {
        for i in 0..n_states {
            for j in (i + 1)..n_states {
                let m = 0.5 * (counts[i * n_states + j] + counts[j * n_states + i]);
                counts[i * n_states + j] = m;
                counts[j * n_states + i] = m;
            }
        }
    }
    let mut t = counts.clone();
    for i in 0..n_states {
        let row = &mut t[i * n_states..(i + 1) * n_states];
        let total: f64 = row.iter().sum();
        if total > 0.0 {
            for v in row.iter_mut() {
                *v /= total;
            }
        } else {
            // unvisited state: self-loop keeps the matrix stochastic
            row[i] = 1.0;
        }
    }
    Ok(Msm { n_states, lag, t, counts })
}

impl Msm {
    /// Stationary distribution via power iteration on `pi T = pi`.
    pub fn stationary(&self) -> Vec<f64> {
        let n = self.n_states;
        let mut pi = vec![1.0 / n as f64; n];
        for _ in 0..10_000 {
            let mut next = vec![0.0f64; n];
            for i in 0..n {
                let pii = pi[i];
                if pii == 0.0 {
                    continue;
                }
                for j in 0..n {
                    next[j] += pii * self.t[i * n + j];
                }
            }
            let norm: f64 = next.iter().sum();
            for v in &mut next {
                *v /= norm;
            }
            let delta: f64 = next.iter().zip(&pi).map(|(a, b)| (a - b).abs()).sum();
            pi = next;
            if delta < 1e-12 {
                break;
            }
        }
        pi
    }

    /// Leading non-unit eigenvalues via pi-weighted deflated power
    /// iteration (valid for reversible T), largest first.
    pub fn eigenvalues(&self, k: usize) -> Vec<f64> {
        let n = self.n_states;
        let pi = self.stationary();
        // reversible T is self-adjoint under the pi inner product
        let dot_pi = |a: &[f64], b: &[f64]| -> f64 {
            a.iter().zip(b).zip(&pi).map(|((x, y), w)| x * y * w).sum()
        };
        let mut found: Vec<(f64, Vec<f64>)> = vec![(1.0, vec![1.0; n])];
        let mut out = Vec::new();
        let mut rng_state = 0x9E3779B97F4A7C15u64;
        for _ in 0..k.min(n.saturating_sub(1)) {
            // deterministic pseudo-random start vector
            let mut v: Vec<f64> = (0..n)
                .map(|_| {
                    rng_state = rng_state.wrapping_mul(6364136223846793005).wrapping_add(1);
                    ((rng_state >> 33) as f64 / (1u64 << 31) as f64) - 1.0
                })
                .collect();
            let mut lambda = 0.0;
            for _ in 0..5_000 {
                // deflate previously found eigenvectors
                for (_, u) in &found {
                    let proj = dot_pi(&v, u) / dot_pi(u, u).max(1e-300);
                    for (vv, uu) in v.iter_mut().zip(u) {
                        *vv -= proj * uu;
                    }
                }
                // w = T v
                let mut w = vec![0.0f64; n];
                for i in 0..n {
                    let row = &self.t[i * n..(i + 1) * n];
                    w[i] = row.iter().zip(&v).map(|(t, x)| t * x).sum();
                }
                let norm = dot_pi(&w, &w).sqrt().max(1e-300);
                let new_lambda = dot_pi(&w, &v) / dot_pi(&v, &v).max(1e-300);
                for x in &mut w {
                    *x /= norm;
                }
                let delta = (new_lambda - lambda).abs();
                lambda = new_lambda;
                v = w;
                if delta < 1e-13 {
                    break;
                }
            }
            found.push((lambda, v));
            out.push(lambda);
        }
        out
    }

    /// Implied timescales t_i = -lag / ln(lambda_i) for the leading
    /// non-unit eigenvalues (frames; multiply by the recording stride
    /// for physical time). Negative/≈zero eigenvalues yield `None`.
    pub fn implied_timescales(&self, k: usize) -> Vec<Option<f64>> {
        self.eigenvalues(k)
            .into_iter()
            .map(|l| {
                if l > 1e-9 && l < 1.0 - 1e-12 {
                    Some(-(self.lag as f64) / l.ln())
                } else {
                    None
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Two-state chain with known rates.
    fn two_state_labels(rng: &mut Rng, n: usize, p01: f64, p10: f64) -> Vec<usize> {
        let mut s = 0usize;
        (0..n)
            .map(|_| {
                let p = if s == 0 { p01 } else { p10 };
                if rng.f64() < p {
                    s = 1 - s;
                }
                s
            })
            .collect()
    }

    #[test]
    fn counts_simple_sequence() {
        let labels = [0usize, 0, 1, 1, 0];
        let c = count_transitions(&labels, 2, 1, &[]).unwrap();
        assert_eq!(c, vec![1.0, 1.0, 1.0, 1.0]);
    }

    #[test]
    fn breaks_skip_spanning_pairs() {
        let labels = [0usize, 0, 1, 1];
        // break between index 1 and 2: the 0->1 transition is an artifact
        let c = count_transitions(&labels, 2, 1, &[2]).unwrap();
        assert_eq!(c, vec![1.0, 0.0, 0.0, 1.0]);
    }

    #[test]
    fn rows_stochastic() {
        let mut rng = Rng::new(0);
        let labels = two_state_labels(&mut rng, 5000, 0.1, 0.3);
        let msm = estimate_msm(&labels, 2, 1, &[], false).unwrap();
        for i in 0..2 {
            let s: f64 = msm.t[i * 2..(i + 1) * 2].iter().sum();
            assert!((s - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn recovers_two_state_rates() {
        let mut rng = Rng::new(1);
        let labels = two_state_labels(&mut rng, 200_000, 0.05, 0.15);
        let msm = estimate_msm(&labels, 2, 1, &[], true).unwrap();
        assert!((msm.t[1] - 0.05).abs() < 0.01, "p01 {}", msm.t[1]);
        assert!((msm.t[2] - 0.15).abs() < 0.01, "p10 {}", msm.t[2]);
        // stationary: pi0/pi1 = p10/p01 = 3
        let pi = msm.stationary();
        assert!((pi[0] / pi[1] - 3.0).abs() < 0.25, "{pi:?}");
        // slowest eigenvalue = 1 - p01 - p10 = 0.8
        let ev = msm.eigenvalues(1);
        assert!((ev[0] - 0.8).abs() < 0.02, "{ev:?}");
        // implied timescale = -1/ln(0.8) ~ 4.48 frames
        let ts = msm.implied_timescales(1)[0].unwrap();
        assert!((ts - 4.48).abs() < 0.5, "{ts}");
    }

    #[test]
    fn unvisited_state_selfloop() {
        let labels = [0usize, 0, 0, 0];
        let msm = estimate_msm(&labels, 3, 1, &[], false).unwrap();
        assert_eq!(msm.t[4], 1.0); // state 1 self-loop
        assert_eq!(msm.t[8], 1.0); // state 2 self-loop
    }

    #[test]
    fn lag_scaling_consistent() {
        // for a Markov chain, lambda(lag k) ~ lambda(lag 1)^k, so the
        // implied timescale is roughly lag-independent
        let mut rng = Rng::new(2);
        let labels = two_state_labels(&mut rng, 300_000, 0.04, 0.1);
        let t1 = estimate_msm(&labels, 2, 1, &[], true)
            .unwrap()
            .implied_timescales(1)[0]
            .unwrap();
        let t3 = estimate_msm(&labels, 2, 3, &[], true)
            .unwrap()
            .implied_timescales(1)[0]
            .unwrap();
        assert!((t1 - t3).abs() / t1 < 0.2, "t1={t1} t3={t3}");
    }

    #[test]
    fn invalid_inputs_rejected() {
        assert!(count_transitions(&[0, 1], 2, 0, &[]).is_err());
        assert!(count_transitions(&[0, 1], 2, 5, &[]).is_err());
        assert!(count_transitions(&[0, 3], 2, 1, &[]).is_err());
    }

    #[test]
    fn md_trajectory_timescale_separation() {
        // end-to-end: macro-state labels from the Langevin simulator must
        // show a slow process (binding/unbinding) well separated from the
        // lag time
        let mut rng = Rng::new(3);
        let cfg = crate::sim::md::MdConfig { stride: 10, ..Default::default() };
        let traj = crate::sim::md::simulate(&mut rng, &cfg, 4000);
        let labels: Vec<usize> = traj.labels.iter().map(|l| l.index()).collect();
        let restart = (4000 / 8).max(1);
        let breaks: Vec<usize> = (1..8).map(|k| k * restart).collect();
        let msm = estimate_msm(&labels, 3, 5, &breaks, true).unwrap();
        let ts = msm.implied_timescales(1)[0];
        let t = ts.expect("slow process exists");
        assert!(t > 15.0, "no slow binding process: t = {t} frames");
    }
}
