//! Tile-path equivalence: the memory-budgeted Gram pipeline is a pure
//! scheduling/placement change. Tiled runs must be bit-identical to
//! whole-panel runs across backends (`native`, `sharded:<p>`), sampling
//! strategies (stride, block), and offload on/off — while the peak
//! resident `K_nl` bytes respect the budget and the report says what the
//! pipeline actually did.
use dkkm::cluster::minibatch::{MiniBatchConfig, MiniBatchKernelKMeans, NativeBackend};
use dkkm::distributed::ShardedBackend;
use dkkm::kernels::{KernelFn, VecGram};
use dkkm::prelude::*;
use dkkm::util::rng::Rng;

fn toy_source(seed: u64, per_cluster: usize) -> VecGram {
    let mut rng = Rng::new(seed);
    let d = dkkm::data::toy2d(&mut rng, per_cluster);
    VecGram::new(d.x, KernelFn::Rbf { gamma: 20.0 }, 2)
}

fn assert_same(a: &dkkm::cluster::MiniBatchResult, b: &dkkm::cluster::MiniBatchResult, tag: &str) {
    assert_eq!(a.labels, b.labels, "labels diverge: {tag}");
    assert_eq!(a.medoids, b.medoids, "medoids diverge: {tag}");
    assert_eq!(a.counts, b.counts, "counts diverge: {tag}");
}

#[test]
fn tiled_equals_whole_native_both_samplings() {
    let g = toy_source(0, 80); // n = 320, B = 4 -> 80x80 panels (25 KiB)
    for sampling in [Sampling::Stride, Sampling::Block] {
        let mut base = MiniBatchConfig::new(4, 4);
        base.sampling = sampling;
        let whole = MiniBatchKernelKMeans::new(base.clone(), &NativeBackend).run(&g).unwrap();
        let budget = 8 * 1024; // forces several tiles + spills per panel
        let mut tiled_cfg = base;
        tiled_cfg.memory_budget = Some(budget);
        let tiled = MiniBatchKernelKMeans::new(tiled_cfg, &NativeBackend).run(&g).unwrap();
        assert_same(&whole, &tiled, &format!("native, {sampling}"));
        assert!(tiled.pipeline.tiles > 4, "{:?}", tiled.pipeline);
        assert!(tiled.pipeline.spilled_tiles > 0, "{:?}", tiled.pipeline);
        assert!(
            tiled.pipeline.peak_resident_bytes <= budget,
            "peak {} over budget {budget} ({sampling})",
            tiled.pipeline.peak_resident_bytes
        );
    }
}

#[test]
fn tiled_equals_whole_sharded() {
    let g = toy_source(1, 80);
    for p in [1usize, 3, 7] {
        let backend = ShardedBackend::new(p);
        let base = MiniBatchConfig::new(4, 2); // 160x160 panels
        let whole = MiniBatchKernelKMeans::new(base.clone(), &backend).run(&g).unwrap();
        let native_whole =
            MiniBatchKernelKMeans::new(base.clone(), &NativeBackend).run(&g).unwrap();
        assert_same(&whole, &native_whole, &format!("sharded:{p} vs native, whole"));
        let budget = 20 * 1024;
        let mut tiled_cfg = base;
        tiled_cfg.memory_budget = Some(budget);
        let tiled = MiniBatchKernelKMeans::new(tiled_cfg, &backend).run(&g).unwrap();
        assert_same(&whole, &tiled, &format!("sharded:{p}, tiled"));
        assert!(tiled.pipeline.peak_resident_bytes <= budget, "{:?}", tiled.pipeline);
    }
}

#[test]
fn tiled_equals_whole_with_offload() {
    let g = toy_source(2, 60);
    let base = MiniBatchConfig::new(4, 3);
    let reference = MiniBatchKernelKMeans::new(base.clone(), &NativeBackend).run(&g).unwrap();
    // offload without budget: whole panels, one producer (Fig.3)
    let mut off = base.clone();
    off.offload = true;
    let offload = MiniBatchKernelKMeans::new(off, &NativeBackend).run(&g).unwrap();
    assert_same(&reference, &offload, "offload whole");
    // offload + budget: tiles stream one batch ahead through the ring
    let mut off_budget = base.clone();
    off_budget.offload = true;
    off_budget.memory_budget = Some(10 * 1024);
    let both = MiniBatchKernelKMeans::new(off_budget, &NativeBackend).run(&g).unwrap();
    assert_same(&reference, &both, "offload + budget");
    assert!(both.overlap.is_some());
    assert!(both.pipeline.peak_resident_bytes <= 10 * 1024, "{:?}", both.pipeline);
    // a wider producer pool is still a pure scheduling change
    let mut pool = base.clone();
    pool.memory_budget = Some(10 * 1024);
    pool.pipeline_workers = Some(3);
    let pooled = MiniBatchKernelKMeans::new(pool, &NativeBackend).run(&g).unwrap();
    assert_same(&reference, &pooled, "worker pool");
    // forced-inline production under a budget is the same run again
    let mut inline = base;
    inline.memory_budget = Some(10 * 1024);
    inline.pipeline_workers = Some(0);
    let inlined = MiniBatchKernelKMeans::new(inline, &NativeBackend).run(&g).unwrap();
    assert_same(&reference, &inlined, "inline tiled");
    assert!(inlined.overlap.is_none());
}

#[test]
fn landmark_fraction_and_tiles_compose() {
    // s < 1 shrinks the panel's column set; the tile path must keep the
    // landmark gather (K_ll) bit-exact through arbitrary lm positions
    let g = toy_source(3, 80);
    let mut base = MiniBatchConfig::new(4, 2);
    base.s = 0.4;
    let whole = MiniBatchKernelKMeans::new(base.clone(), &NativeBackend).run(&g).unwrap();
    let mut tiled_cfg = base;
    tiled_cfg.memory_budget = Some(6 * 1024);
    let tiled = MiniBatchKernelKMeans::new(tiled_cfg, &NativeBackend).run(&g).unwrap();
    assert_same(&whole, &tiled, "s=0.4 tiled");
}

#[test]
fn builder_threads_budget_through_session() {
    let exp = || {
        Experiment::on(DatasetSpec::Toy2d { per_cluster: 60 })
            .clusters(4)
            .batches(2)
            .sigma_factor(0.1)
    };
    let whole = exp().build().unwrap().fit().unwrap();
    let budget = 16 * 1024; // 120x120 panels = 56 KiB each
    let tiled = exp().memory_budget(budget).build().unwrap().fit().unwrap();
    assert_eq!(whole.result.labels, tiled.result.labels);
    assert_eq!(whole.result.medoids, tiled.result.medoids);
    assert_eq!(whole.train_accuracy, tiled.train_accuracy);
    // the report records what the pipeline did
    assert_eq!(tiled.pipeline.budget_bytes, Some(budget));
    assert!(tiled.pipeline.tiles > 2);
    assert!(tiled.pipeline.peak_resident_bytes <= budget);
    let j = tiled.to_json();
    let parsed = dkkm::util::json::Json::parse(&j.to_string()).unwrap();
    let pipe = parsed.get("pipeline").expect("pipeline in report json");
    assert_eq!(pipe.get("budget_bytes").and_then(|v| v.as_usize()), Some(budget));
    assert!(pipe.get("peak_resident_bytes").and_then(|v| v.as_usize()).unwrap() <= budget);
    assert!(pipe.get("overlap_efficiency").and_then(|v| v.as_f64()).is_some());
    // whole-panel runs carry honest accounting too
    assert_eq!(whole.pipeline.budget_bytes, None);
    assert_eq!(whole.pipeline.tiles, 2);
}

#[test]
fn builder_budget_composes_with_sharded_engine() {
    let exp = || {
        Experiment::on(DatasetSpec::Toy2d { per_cluster: 60 })
            .clusters(4)
            .batches(2)
            .sigma_factor(0.1)
    };
    let native = exp().build().unwrap().fit().unwrap();
    let sharded = exp()
        .backend("sharded:3")
        .memory_budget(16 * 1024)
        .build()
        .unwrap()
        .fit()
        .unwrap();
    assert_eq!(native.result.labels, sharded.result.labels);
    assert_eq!(native.result.medoids, sharded.result.medoids);
    assert_eq!(sharded.engine.used, "sharded:3");
    assert!(sharded.pipeline.peak_resident_bytes <= 16 * 1024);
}

#[test]
fn oversized_c_at_fit_time_is_a_structured_error() {
    // build validates the budget for C=4 (L = max(round(s*nb), C) = 6);
    // a later fit_clusters with a C that outgrows the budget must be a
    // Config error, not the pipeline's runtime panic
    let session = Experiment::on(DatasetSpec::Toy2d { per_cluster: 60 })
        .clusters(4)
        .batches(2)
        .landmark_fraction(0.05)
        .sigma_factor(0.1)
        .memory_budget(2000)
        .build()
        .unwrap();
    assert!(session.fit_clusters(4).is_ok());
    // C=120 keeps B*C <= n but needs L=120 columns: 2400 B > 2000 B
    let err = session.fit_clusters(120).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("memory_budget") && msg.contains("C=120"), "{msg}");
}

#[test]
fn infeasible_budget_is_a_build_error() {
    let err = Experiment::on(DatasetSpec::Toy2d { per_cluster: 60 })
        .clusters(4)
        .batches(2)
        .memory_budget(64)
        .build()
        .unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("memory_budget") && msg.contains("64"), "{msg}");
}
