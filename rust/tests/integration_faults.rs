//! Fault-tolerance equivalence suite: injected failures change the
//! schedule, never the math. For every fault class — node death at a
//! collective, a dropped straggler (deadline timeout), transient
//! spill-read failures — the recovered run must be bit-identical to the
//! fault-free serial reference across node counts, and the accounting
//! must record what actually happened (honestly zero on clean runs).
use std::sync::Arc;

use dkkm::cluster::minibatch::{MiniBatchConfig, MiniBatchKernelKMeans, NativeBackend};
use dkkm::coordinator::{DatasetSpec, Experiment};
use dkkm::distributed::{FaultPlan, FaultSession, ShardedBackend};
use dkkm::kernels::{KernelFn, VecGram};
use dkkm::util::error::Error;
use dkkm::util::rng::Rng;

fn toy_source(seed: u64, per_cluster: usize) -> VecGram {
    let mut rng = Rng::new(seed);
    let d = dkkm::data::toy2d(&mut rng, per_cluster);
    VecGram::new(d.x, KernelFn::Rbf { gamma: 20.0 }, 2)
}

fn session(spec: &str) -> Arc<FaultSession> {
    Arc::new(FaultSession::new(FaultPlan::parse(spec).unwrap()))
}

#[test]
fn node_death_recovers_bit_identically_across_p() {
    let g = toy_source(0, 60); // n = 240
    let cfg = MiniBatchConfig::new(4, 2);
    let reference = MiniBatchKernelKMeans::new(cfg.clone(), &NativeBackend).run(&g).unwrap();
    for p in [2usize, 3, 4, 8] {
        let faults = session("kill:1@0");
        let backend = ShardedBackend::new(p).with_faults(faults.clone());
        let run = MiniBatchKernelKMeans::new(cfg.clone(), &backend).run(&g).unwrap();
        assert_eq!(reference.labels, run.labels, "labels diverge at p={p}");
        assert_eq!(reference.medoids, run.medoids, "medoids diverge at p={p}");
        assert_eq!(reference.counts, run.counts, "counts diverge at p={p}");
        let rep = faults.report();
        assert_eq!(rep.injected, 1, "p={p}: {rep:?}");
        assert!(rep.detected >= 1, "p={p}: {rep:?}");
        assert!(rep.recovered >= 1, "p={p}: {rep:?}");
        assert_eq!(rep.reshard_events, 1, "p={p}: {rep:?}");
        assert!(rep.recovery_seconds >= 0.0, "p={p}: {rep:?}");
    }
}

#[test]
fn deadline_timeout_drops_the_straggler_bit_identically() {
    let g = toy_source(1, 60);
    let cfg = MiniBatchConfig::new(4, 2);
    let reference = MiniBatchKernelKMeans::new(cfg.clone(), &NativeBackend).run(&g).unwrap();
    for p in [2usize, 3, 4, 8] {
        // rank 1 sleeps through its first collective far past the
        // deadline: peers must drop it and re-shard, not hang
        let faults = session("delay:1@0:200; deadline:40");
        let backend = ShardedBackend::new(p).with_faults(faults.clone());
        let run = MiniBatchKernelKMeans::new(cfg.clone(), &backend).run(&g).unwrap();
        assert_eq!(reference.labels, run.labels, "labels diverge at p={p}");
        assert_eq!(reference.medoids, run.medoids, "medoids diverge at p={p}");
        let rep = faults.report();
        assert_eq!(rep.injected, 1, "p={p}: {rep:?}");
        assert!(rep.recovered >= 1, "p={p}: {rep:?}");
    }
}

#[test]
fn transient_spill_read_failures_retry_bit_identically() {
    let g = toy_source(2, 80); // n = 320, B = 4 -> 80x80 panels
    let mut cfg = MiniBatchConfig::new(4, 4);
    cfg.memory_budget = Some(8 * 1024); // forces tiles + spills per panel
    let reference = MiniBatchKernelKMeans::new(cfg.clone(), &NativeBackend).run(&g).unwrap();
    assert!(reference.pipeline.spilled_tiles > 0, "{:?}", reference.pipeline);
    for p in [2usize, 3, 4, 8] {
        let faults = session("spill:2");
        let mut fcfg = cfg.clone();
        fcfg.faults = Some(faults.clone());
        let backend = ShardedBackend::new(p).with_faults(faults.clone());
        let run = MiniBatchKernelKMeans::new(fcfg, &backend).run(&g).unwrap();
        assert_eq!(reference.labels, run.labels, "labels diverge at p={p}");
        assert_eq!(reference.medoids, run.medoids, "medoids diverge at p={p}");
        let rep = faults.report();
        assert_eq!(rep.injected, 2, "p={p}: {rep:?}");
        assert!(rep.spill_retries >= 2, "p={p}: {rep:?}");
    }
}

fn toy_exp() -> Experiment {
    let spec = DatasetSpec::Toy2d { per_cluster: 100 };
    Experiment::on(spec).clusters(4).batches(2).sigma_factor(0.1)
}

#[test]
fn experiment_level_kill_fault_matches_native_and_reports() {
    let native = toy_exp().build().unwrap().fit().unwrap();
    assert!(native.faults.is_clean(), "clean run reported faults: {:?}", native.faults);
    let report = toy_exp()
        .backend("sharded:4")
        .fault("kill:2@0")
        .build()
        .unwrap()
        .fit()
        .unwrap();
    assert_eq!(native.result.labels, report.result.labels);
    assert_eq!(native.result.medoids, report.result.medoids);
    assert_eq!(report.faults.injected, 1, "{:?}", report.faults);
    assert!(report.faults.recovered >= 1, "{:?}", report.faults);
    assert_eq!(report.faults.reshard_events, 1, "{:?}", report.faults);
    // the machine-readable report carries the same accounting
    let j = report.to_json();
    let f = j.get("faults").expect("faults block");
    assert_eq!(f.get("injected").and_then(|v| v.as_usize()), Some(1));
    assert_eq!(f.get("reshard_events").and_then(|v| v.as_usize()), Some(1));
}

#[test]
fn interrupted_fit_resumes_to_identical_labels() {
    let dir = std::env::temp_dir().join(format!("dkkm_faults_resume_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let exp = || toy_exp().batches(4);
    let clean = exp().build().unwrap().fit().unwrap();

    // interrupt after 2 of 4 epochs: fit() fails structurally, leaving
    // a checkpoint behind
    let err = exp()
        .checkpoint_dir(&dir)
        .fault("interrupt:2")
        .build()
        .unwrap()
        .fit()
        .unwrap_err();
    assert!(matches!(err, Error::Interrupted { epoch: 2 }), "{err:?}");
    assert!(std::fs::read_dir(&dir).unwrap().count() >= 1, "no checkpoint written");

    // the resumed fit finishes epochs 2..4 and lands on the same answer
    let resumed = exp()
        .checkpoint_dir(&dir)
        .resume(true)
        .build()
        .unwrap()
        .fit()
        .unwrap();
    assert_eq!(clean.result.labels, resumed.result.labels);
    assert_eq!(clean.result.medoids, resumed.result.medoids);
    assert_eq!(clean.result.counts, resumed.result.counts);
    assert_eq!(resumed.faults.resumed_from_epoch, Some(2), "{:?}", resumed.faults);
    assert!(resumed.faults.checkpoints_written >= 1, "{:?}", resumed.faults);
    // a clean finish removes its checkpoint file
    assert_eq!(std::fs::read_dir(&dir).unwrap().count(), 0, "stale checkpoint left behind");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn clean_runs_report_zero_faults_on_every_engine() {
    for backend in ["native", "sharded:3"] {
        let report = toy_exp().backend(backend).build().unwrap().fit().unwrap();
        assert!(
            report.faults.is_clean(),
            "clean {backend} run reported faults: {:?}",
            report.faults
        );
    }
}
