//! Shared kernel k-means update math (Eq.4-6 / Eq.15-17).
//!
//! Cluster state during the inner loop is the landmark label vector; this
//! module turns kernel blocks + landmark labels into cluster sizes,
//! compactness `g`, average similarity `f`, and argmin label updates.
//! Both the serial mini-batch driver and the distributed shards call
//! these; the PJRT runtime reproduces the same math inside one fused
//! executable (`inner_n*_l*_c*` artifacts).
//!
//! The kernel block arrives as a [`GramView`]: either a whole `Mat` or a
//! stream of budget-sized tiles (`kernels::tiles`). Every per-row value
//! is computed from that row's kernel entries alone, so the tile-wise
//! sweep is bit-identical to the whole-panel one.
use crate::kernels::GramView;
use crate::linalg::Mat;

/// Per-cluster statistics derived from landmark labels.
#[derive(Clone, Debug)]
pub struct ClusterStats {
    /// |w_j| — landmark count per cluster.
    pub counts: Vec<usize>,
    /// 1/|w_j| with empty clusters mapped to 0 (paper's alpha = 0 rule).
    pub inv: Vec<f32>,
    /// Cluster compactness g_j (Eq.5/16).
    pub g: Vec<f32>,
}

impl ClusterStats {
    /// Compute counts, inv and g from the landmark-vs-landmark kernel
    /// block and landmark labels. O(L^2) — L is small by construction.
    pub fn compute(k_ll: &Mat, lm_labels: &[usize], c: usize) -> ClusterStats {
        let l = lm_labels.len();
        assert_eq!(k_ll.rows(), l);
        assert_eq!(k_ll.cols(), l);
        let mut counts = vec![0usize; c];
        for &u in lm_labels {
            assert!(u < c, "label {u} out of range {c}");
            counts[u] += 1;
        }
        let inv: Vec<f32> = counts
            .iter()
            .map(|&s| if s > 0 { 1.0 / s as f32 } else { 0.0 })
            .collect();
        // g_j = inv_j^2 sum_{m,n in j} K_mn, accumulated row-wise:
        // for each row m, add inv^2 * sum_{n in j(m)==j} ... grouped by
        // (label(m), label(n)) pairs where only equal labels contribute.
        let mut g = vec![0.0f64; c];
        for m in 0..l {
            let um = lm_labels[m];
            if counts[um] == 0 {
                continue;
            }
            let row = k_ll.row(m);
            let mut acc = 0.0f64;
            for (n, &kv) in row.iter().enumerate() {
                if lm_labels[n] == um {
                    acc += kv as f64;
                }
            }
            g[um] += acc;
        }
        let g: Vec<f32> = g
            .iter()
            .zip(&inv)
            .map(|(&q, &iv)| (q as f32) * iv * iv)
            .collect();
        ClusterStats { counts, inv, g }
    }

    /// True where the cluster is non-empty.
    pub fn valid(&self) -> Vec<bool> {
        self.counts.iter().map(|&s| s > 0).collect()
    }
}

/// Cluster average similarity f (Eq.6/17): `f[r][j] = inv_j *
/// sum_{m: label(m)=j} K[r][m]` for every row of the block.
pub fn similarity_f(k_block: &Mat, lm_labels: &[usize], stats: &ClusterStats) -> Mat {
    let c = stats.counts.len();
    let rows = k_block.rows();
    assert_eq!(k_block.cols(), lm_labels.len());
    let mut f = Mat::zeros(rows, c);
    for r in 0..rows {
        let krow = k_block.row(r);
        let frow = f.row_mut(r);
        for (m, &kv) in krow.iter().enumerate() {
            frow[lm_labels[m]] += kv;
        }
        for (j, v) in frow.iter_mut().enumerate() {
            *v *= stats.inv[j];
        }
    }
    f
}

/// Label update (Eq.4/15): `argmin_j g_j - 2 f_rj` over non-empty
/// clusters. Returns one label per row of `f`.
pub fn argmin_labels(f: &Mat, stats: &ClusterStats) -> Vec<usize> {
    let c = stats.counts.len();
    assert_eq!(f.cols(), c);
    let mut labels = Vec::with_capacity(f.rows());
    for r in 0..f.rows() {
        let frow = f.row(r);
        let mut best = usize::MAX;
        let mut best_d = f32::INFINITY;
        for j in 0..c {
            if stats.counts[j] == 0 {
                continue;
            }
            let d = stats.g[j] - 2.0 * frow[j];
            if d < best_d {
                best_d = d;
                best = j;
            }
        }
        debug_assert!(best != usize::MAX, "all clusters empty");
        labels.push(best);
    }
    labels
}

/// Cluster average similarity f over a tiled view: assembles the full
/// `rows x C` matrix tile by tile (C is small, so f always fits).
pub fn similarity_f_view(view: &GramView<'_>, lm_labels: &[usize], stats: &ClusterStats) -> Mat {
    let c = stats.counts.len();
    let mut f = Mat::zeros(view.rows(), c);
    for t in 0..view.n_tiles() {
        let (lo, _hi) = view.tile_range(t);
        let tile = view.tile(t);
        let ft = similarity_f(tile.mat(), lm_labels, stats);
        for r in 0..ft.rows() {
            f.row_mut(lo + r).copy_from_slice(ft.row(r));
        }
    }
    f
}

/// One fused inner-loop iteration on the native path: compute stats from
/// `k_ll`, then f and labels tile-wise over the view. Mirrors the PJRT
/// `inner_*` artifact.
pub fn inner_iteration_view(
    view: &GramView<'_>,
    k_ll: &Mat,
    lm_labels: &[usize],
    c: usize,
) -> (Vec<usize>, ClusterStats) {
    let stats = ClusterStats::compute(k_ll, lm_labels, c);
    let mut labels = Vec::with_capacity(view.rows());
    for t in 0..view.n_tiles() {
        let tile = view.tile(t);
        let f = similarity_f(tile.mat(), lm_labels, &stats);
        labels.extend(argmin_labels(&f, &stats));
    }
    (labels, stats)
}

/// Whole-matrix convenience wrapper over [`inner_iteration_view`].
pub fn inner_iteration(
    k_block: &Mat,
    k_ll: &Mat,
    lm_labels: &[usize],
    c: usize,
) -> (Vec<usize>, ClusterStats) {
    inner_iteration_view(&GramView::Whole(k_block), k_ll, lm_labels, c)
}

/// Partial kernel k-means cost (Eq.1/9) of a labelled block:
/// `sum_r K_rr - 2 f_{r, u_r} + g_{u_r}`.
pub fn block_cost(
    diag: &[f32],
    f: &Mat,
    labels: &[usize],
    stats: &ClusterStats,
) -> f64 {
    assert_eq!(diag.len(), labels.len());
    let mut total = 0.0f64;
    for (r, &u) in labels.iter().enumerate() {
        total += diag[r] as f64 - 2.0 * f.at(r, u) as f64 + stats.g[u] as f64;
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{GramSource, KernelFn, VecGram};
    use crate::util::rng::Rng;

    fn setup(seed: u64, n: usize, l: usize, c: usize) -> (VecGram, Vec<usize>, Vec<usize>, Vec<usize>) {
        let mut rng = Rng::new(seed);
        let x = Mat::from_fn(n, 4, |_, _| rng.normal32(0.0, 2.0));
        let g = VecGram::new(x, KernelFn::Rbf { gamma: 0.2 }, 2);
        let rows: Vec<usize> = (0..n).collect();
        let lms: Vec<usize> = (0..l).collect();
        let labels: Vec<usize> = (0..l).map(|_| rng.below(c)).collect();
        (g, rows, lms, labels)
    }

    #[test]
    fn stats_counts_and_inv() {
        let (g, _, lms, labels) = setup(0, 40, 20, 5);
        let kll = g.block_mat(&lms, &lms);
        let stats = ClusterStats::compute(&kll, &labels, 5);
        assert_eq!(stats.counts.iter().sum::<usize>(), 20);
        for j in 0..5 {
            if stats.counts[j] > 0 {
                assert!((stats.inv[j] - 1.0 / stats.counts[j] as f32).abs() < 1e-7);
            } else {
                assert_eq!(stats.inv[j], 0.0);
            }
        }
    }

    #[test]
    fn g_matches_naive_quadratic_form() {
        let (g, _, lms, labels) = setup(1, 30, 16, 4);
        let kll = g.block_mat(&lms, &lms);
        let stats = ClusterStats::compute(&kll, &labels, 4);
        for j in 0..4 {
            let mut want = 0.0f64;
            for m in 0..16 {
                for n in 0..16 {
                    if labels[m] == j && labels[n] == j {
                        want += kll.at(m, n) as f64;
                    }
                }
            }
            let sz = stats.counts[j] as f64;
            let want = if sz > 0.0 { want / (sz * sz) } else { 0.0 };
            assert!((stats.g[j] as f64 - want).abs() < 1e-4, "cluster {j}");
        }
    }

    #[test]
    fn f_matches_naive() {
        let (g, rows, lms, labels) = setup(2, 25, 12, 3);
        let kb = g.block_mat(&rows, &lms);
        let kll = g.block_mat(&lms, &lms);
        let stats = ClusterStats::compute(&kll, &labels, 3);
        let f = similarity_f(&kb, &labels, &stats);
        for r in 0..25 {
            for j in 0..3 {
                let mut want = 0.0f32;
                for m in 0..12 {
                    if labels[m] == j {
                        want += kb.at(r, m);
                    }
                }
                want *= stats.inv[j];
                assert!((f.at(r, j) - want).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn argmin_skips_empty_clusters() {
        let (g, rows, lms, mut labels) = setup(3, 20, 10, 6);
        labels.iter_mut().for_each(|u| *u %= 3); // clusters 3..6 empty
        let kb = g.block_mat(&rows, &lms);
        let kll = g.block_mat(&lms, &lms);
        let (new_labels, stats) = inner_iteration(&kb, &kll, &labels, 6);
        assert!(new_labels.iter().all(|&u| u < 3));
        assert_eq!(&stats.counts[3..], &[0, 0, 0]);
    }

    #[test]
    fn iteration_reaches_fixed_point_on_separated_data() {
        // two tight blobs far apart: one iteration from any init where
        // both clusters are seeded recovers the partition
        let mut rng = Rng::new(4);
        let x = Mat::from_fn(40, 2, |r, _| {
            let base = if r < 20 { 0.0 } else { 50.0 };
            rng.normal32(base, 0.5)
        });
        let g = VecGram::new(x, KernelFn::Rbf { gamma: 0.01 }, 1);
        let rows: Vec<usize> = (0..40).collect();
        let kb = g.block_mat(&rows, &rows);
        // seed: alternate labels (both clusters present in both blobs)
        let init: Vec<usize> = (0..40).map(|i| i % 2).collect();
        let (l1, _) = inner_iteration(&kb, &kb, &init, 2);
        let (l2, _) = inner_iteration(&kb, &kb, &l1, 2);
        let (l3, _) = inner_iteration(&kb, &kb, &l2, 2);
        assert_eq!(l2, l3, "not converged");
        // blob membership must match
        for w in l3[..20].windows(2) {
            assert_eq!(w[0], w[1]);
        }
        for w in l3[20..].windows(2) {
            assert_eq!(w[0], w[1]);
        }
        assert_ne!(l3[0], l3[39]);
    }

    #[test]
    fn block_cost_is_nonnegative_for_psd_kernel() {
        let (g, rows, lms, labels) = setup(5, 30, 30, 4);
        // landmarks == rows here, so this is the exact full-batch cost
        let kb = g.block_mat(&rows, &lms);
        let stats = ClusterStats::compute(&kb, &labels, 4);
        let f = similarity_f(&kb, &labels, &stats);
        let mut diag = vec![0.0f32; 30];
        g.diag(&rows, &mut diag);
        // cost with *consistent* labels (f/g from same labels)
        let cost = block_cost(&diag, &f, &labels, &stats);
        assert!(cost >= -1e-3, "cost {cost}");
    }

    #[test]
    fn cost_decreases_under_iteration() {
        let (g, rows, lms, labels) = setup(6, 50, 50, 5);
        let kb = g.block_mat(&rows, &lms);
        let mut labels = labels;
        let mut prev = f64::INFINITY;
        let mut diag = vec![0.0f32; 50];
        g.diag(&rows, &mut diag);
        for _ in 0..10 {
            let stats = ClusterStats::compute(&kb, &labels, 5);
            let f = similarity_f(&kb, &labels, &stats);
            let cost = block_cost(&diag, &f, &labels, &stats);
            assert!(cost <= prev + 1e-3, "cost rose: {prev} -> {cost}");
            prev = cost;
            let new = argmin_labels(&f, &stats);
            if new == labels {
                break;
            }
            labels = new;
        }
    }
}
