//! Distribution-strategy invariants (paper §3.3): the row-sharded
//! execution is a pure scheduling change — results must be identical to
//! serial for every node count, on every dataset family.
use dkkm::cluster::minibatch::{MiniBatchConfig, MiniBatchKernelKMeans, NativeBackend};
use dkkm::data::{synthetic_mnist, synthetic_rcv1, toy2d};
use dkkm::distributed::{NetModel, ScalingSimulator, ShardedBackend, Topology};
use dkkm::distributed::scaling::synthetic_calibration;
use dkkm::kernels::{KernelFn, VecGram};
use dkkm::util::rng::Rng;

fn run_pair(g: &VecGram, c: usize, b: usize, p: usize) -> (Vec<usize>, Vec<usize>) {
    let cfg = MiniBatchConfig::new(c, b);
    let native = MiniBatchKernelKMeans::new(cfg.clone(), &NativeBackend).run(g).unwrap();
    let backend = ShardedBackend::new(p);
    let sharded = MiniBatchKernelKMeans::new(cfg, &backend).run(g).unwrap();
    (native.labels, sharded.labels)
}

#[test]
fn sharded_identical_on_toy_all_p() {
    let mut rng = Rng::new(0);
    let data = toy2d(&mut rng, 50);
    let g = VecGram::new(data.x, KernelFn::Rbf { gamma: 15.0 }, 1);
    for p in [1usize, 2, 3, 7, 16] {
        let (a, b) = run_pair(&g, 4, 2, p);
        assert_eq!(a, b, "labels diverge at p={p}");
    }
}

#[test]
fn sharded_identical_on_mnist() {
    let mut rng = Rng::new(1);
    let data = synthetic_mnist(&mut rng, 500);
    let g = VecGram::new(data.x, KernelFn::rbf_from_sigma(30.0), 1);
    let (a, b) = run_pair(&g, 10, 4, 5);
    assert_eq!(a, b);
}

#[test]
fn sharded_identical_on_rcv1_with_landmarks() {
    let mut rng = Rng::new(2);
    let data = synthetic_rcv1(&mut rng, 600, 8, 3000, 32);
    let g = VecGram::new(data.x, KernelFn::rbf_from_sigma(4.0), 1);
    let mut cfg = MiniBatchConfig::new(8, 3);
    cfg.s = 0.5; // landmark sparsification active
    let native = MiniBatchKernelKMeans::new(cfg.clone(), &NativeBackend).run(&g).unwrap();
    let backend = ShardedBackend::new(4);
    let sharded = MiniBatchKernelKMeans::new(cfg, &backend).run(&g).unwrap();
    assert_eq!(native.labels, sharded.labels);
    assert_eq!(native.medoids, sharded.medoids);
    assert_eq!(native.counts, sharded.counts);
}

#[test]
fn more_nodes_than_rows_degenerates_cleanly() {
    let mut rng = Rng::new(3);
    let data = toy2d(&mut rng, 10); // 40 samples
    let g = VecGram::new(data.x, KernelFn::Rbf { gamma: 10.0 }, 1);
    let (a, b) = run_pair(&g, 4, 1, 64);
    assert_eq!(a, b);
}

#[test]
fn scaling_model_shape_invariants() {
    // Fig.6's qualitative structure as a property of the model:
    // total time decreasing in P until comm dominates, then flattening;
    // efficiency monotone non-increasing
    for topo in [Topology::BgqTorus5D, Topology::InfinibandQdr] {
        let sim = ScalingSimulator {
            net: NetModel::new(topo),
            n: 60_000,
            l: 60_000,
            c: 10,
            iters: 20,
        };
        let ps: Vec<usize> = (0..12).map(|k| 1usize << k).collect();
        let rep = sim.sweep(synthetic_calibration(), &ps);
        for w in rep.points.windows(2) {
            assert!(
                w[1].efficiency <= w[0].efficiency + 1e-9,
                "efficiency rose: {w:?}"
            );
        }
        // communication share strictly grows with P
        let first = &rep.points[2];
        let last = rep.points.last().unwrap();
        assert!(last.comm_s / last.total_s > first.comm_s / first.total_s);
    }
}
