//! Synthetic MNIST-like digits (substitution for MNIST — no network
//! access; DESIGN.md §3).
//!
//! Ten 28x28 class prototypes are rendered from a classic 5x7 digit
//! bitmap font, upscaled and blurred; each sample applies a random
//! translation, per-pixel Gaussian noise, random intensity scaling and
//! dropout. This preserves what the paper's MNIST experiments exercise:
//! 784-dimensional dense features, 10 classes with real inter-class
//! confusion (1/7, 3/8, 5/6 ...), and enough intra-class variation that
//! clustering accuracy sits well below 100%.
use super::Dataset;
use crate::linalg::Mat;
use crate::util::rng::Rng;

pub const SIDE: usize = 28;
pub const DIM: usize = SIDE * SIDE;

/// 5x7 bitmap font for digits 0-9 (rows top->bottom, 5 bits each).
const FONT: [[u8; 7]; 10] = [
    [0b01110, 0b10001, 0b10011, 0b10101, 0b11001, 0b10001, 0b01110], // 0
    [0b00100, 0b01100, 0b00100, 0b00100, 0b00100, 0b00100, 0b01110], // 1
    [0b01110, 0b10001, 0b00001, 0b00010, 0b00100, 0b01000, 0b11111], // 2
    [0b11111, 0b00010, 0b00100, 0b00010, 0b00001, 0b10001, 0b01110], // 3
    [0b00010, 0b00110, 0b01010, 0b10010, 0b11111, 0b00010, 0b00010], // 4
    [0b11111, 0b10000, 0b11110, 0b00001, 0b00001, 0b10001, 0b01110], // 5
    [0b00110, 0b01000, 0b10000, 0b11110, 0b10001, 0b10001, 0b01110], // 6
    [0b11111, 0b00001, 0b00010, 0b00100, 0b01000, 0b01000, 0b01000], // 7
    [0b01110, 0b10001, 0b10001, 0b01110, 0b10001, 0b10001, 0b01110], // 8
    [0b01110, 0b10001, 0b10001, 0b01111, 0b00001, 0b00010, 0b01100], // 9
];

/// Render the blurred 28x28 prototype for one digit.
fn prototype(digit: usize) -> Vec<f32> {
    let mut img = vec![0.0f32; DIM];
    // upscale 5x7 -> 20x28-ish: each font pixel becomes a 4x4 block,
    // centred at (4, 4)
    for (r, bits) in FONT[digit].iter().enumerate() {
        for c in 0..5 {
            if bits & (1 << (4 - c)) != 0 {
                for dr in 0..4 {
                    for dc in 0..4 {
                        let rr = 2 + r * 3 + dr;
                        let cc = 4 + c * 4 + dc;
                        if rr < SIDE && cc < SIDE {
                            img[rr * SIDE + cc] = 1.0;
                        }
                    }
                }
            }
        }
    }
    // 3x3 box blur x2 for soft strokes
    for _ in 0..2 {
        let src = img.clone();
        for r in 0..SIDE {
            for c in 0..SIDE {
                let mut acc = 0.0;
                let mut cnt = 0.0;
                for dr in -1i32..=1 {
                    for dc in -1i32..=1 {
                        let rr = r as i32 + dr;
                        let cc = c as i32 + dc;
                        if (0..SIDE as i32).contains(&rr) && (0..SIDE as i32).contains(&cc) {
                            acc += src[rr as usize * SIDE + cc as usize];
                            cnt += 1.0;
                        }
                    }
                }
                img[r * SIDE + c] = acc / cnt;
            }
        }
    }
    img
}

/// Generate one noisy sample of `digit` into `out`.
fn sample_into(rng: &mut Rng, proto: &[f32], out: &mut [f32]) {
    let dx = rng.range(0, 5) as i32 - 2;
    let dy = rng.range(0, 5) as i32 - 2;
    let gain = 0.8 + 0.4 * rng.f32();
    let noise = 0.17f32;
    for r in 0..SIDE {
        for c in 0..SIDE {
            let sr = r as i32 - dy;
            let sc = c as i32 - dx;
            let base = if (0..SIDE as i32).contains(&sr) && (0..SIDE as i32).contains(&sc)
            {
                proto[sr as usize * SIDE + sc as usize]
            } else {
                0.0
            };
            let mut v = base * gain + rng.normal32(0.0, noise);
            // random dropout of bright pixels (stroke breaks)
            if v > 0.5 && rng.f32() < 0.05 {
                v = 0.0;
            }
            out[r * SIDE + c] = v.clamp(0.0, 1.0);
        }
    }
}

/// `n` synthetic digit samples, classes balanced, order shuffled.
pub fn synthetic_mnist(rng: &mut Rng, n: usize) -> Dataset {
    let protos: Vec<Vec<f32>> = (0..10).map(prototype).collect();
    let mut x = Mat::zeros(n, DIM);
    let mut y = vec![0usize; n];
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    for (slot, &i) in order.iter().enumerate() {
        let digit = i % 10;
        sample_into(rng, &protos[digit], x.row_mut(slot));
        y[slot] = digit;
    }
    Dataset::new("synthetic-mnist", x, y, 10)
}

/// Noisy MNIST (paper §4): each base sample is replicated `copies` times
/// with uniform noise added to 20% of the features, normalized layout kept.
pub fn noisy_mnist(rng: &mut Rng, base: &Dataset, copies: usize) -> Dataset {
    let n = base.n() * copies;
    let d = base.d();
    let mut x = Mat::zeros(n, d);
    let mut y = vec![0usize; n];
    let n_noisy = d / 5; // 20% of features
    for i in 0..base.n() {
        for k in 0..copies {
            let row = i * copies + k;
            x.row_mut(row).copy_from_slice(base.x.row(i));
            y[row] = base.y[i];
            for _ in 0..n_noisy {
                let j = rng.below(d);
                let v = x.at(row, j) + rng.f32();
                x.set(row, j, v.min(1.0));
            }
        }
    }
    Dataset::new("noisy-mnist", x, y, base.classes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes() {
        let mut rng = Rng::new(0);
        let d = synthetic_mnist(&mut rng, 200);
        assert_eq!(d.n(), 200);
        assert_eq!(d.d(), 784);
        assert_eq!(d.classes, 10);
    }

    #[test]
    fn balanced_classes() {
        let mut rng = Rng::new(1);
        let d = synthetic_mnist(&mut rng, 500);
        for c in 0..10 {
            assert_eq!(d.y.iter().filter(|&&v| v == c).count(), 50);
        }
    }

    #[test]
    fn values_in_unit_range() {
        let mut rng = Rng::new(2);
        let d = synthetic_mnist(&mut rng, 50);
        assert!(d.x.data().iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn same_class_closer_than_cross_class() {
        // the property clustering depends on: mean intra-class distance
        // < mean inter-class distance
        let mut rng = Rng::new(3);
        let d = synthetic_mnist(&mut rng, 300);
        let dist = |a: usize, b: usize| -> f32 {
            d.x.row(a)
                .iter()
                .zip(d.x.row(b))
                .map(|(u, v)| (u - v) * (u - v))
                .sum()
        };
        let mut intra = (0.0f64, 0usize);
        let mut inter = (0.0f64, 0usize);
        for i in 0..100 {
            for j in (i + 1)..100 {
                let dd = dist(i, j) as f64;
                if d.y[i] == d.y[j] {
                    intra = (intra.0 + dd, intra.1 + 1);
                } else {
                    inter = (inter.0 + dd, inter.1 + 1);
                }
            }
        }
        let intra_mean = intra.0 / intra.1 as f64;
        let inter_mean = inter.0 / inter.1 as f64;
        assert!(
            intra_mean < inter_mean * 0.8,
            "intra {intra_mean} vs inter {inter_mean}"
        );
    }

    #[test]
    fn prototypes_distinct() {
        let protos: Vec<Vec<f32>> = (0..10).map(prototype).collect();
        for a in 0..10 {
            for b in (a + 1)..10 {
                let d2: f32 = protos[a]
                    .iter()
                    .zip(&protos[b])
                    .map(|(u, v)| (u - v) * (u - v))
                    .sum();
                assert!(d2 > 1.0, "prototypes {a} and {b} too similar: {d2}");
            }
        }
    }

    #[test]
    fn noisy_replicates() {
        let mut rng = Rng::new(4);
        let base = synthetic_mnist(&mut rng, 40);
        let noisy = noisy_mnist(&mut rng, &base, 5);
        assert_eq!(noisy.n(), 200);
        assert_eq!(noisy.y[0..5], vec![base.y[0]; 5][..]);
        // noise added: copies differ from each other
        assert_ne!(noisy.x.row(0), noisy.x.row(1));
        // but stay within [0, 1]
        assert!(noisy.x.data().iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn deterministic() {
        let a = synthetic_mnist(&mut Rng::new(9), 30);
        let b = synthetic_mnist(&mut Rng::new(9), 30);
        assert_eq!(a.x.data(), b.x.data());
    }
}
