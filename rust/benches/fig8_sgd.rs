//! Fig.8 — clustering accuracy vs the number of mini-batches B: the
//! paper's iterate-to-convergence mini-batch kernel k-means (black)
//! against Sculley's SGD mini-batch k-means (red) on MNIST, C = 10,
//! sigma = 4 d_max (linear-mimicking kernel).
//!
//! Paper's claims to reproduce:
//!   * our algorithm is best at small B and degrades gently,
//!   * SGD accuracy is roughly flat in B (it fixes its own batch size),
//!   * our variance is visibly smaller than SGD's.
use dkkm::baselines::{sgd_kmeans, SgdConfig};
use dkkm::coordinator::build_dataset;
use dkkm::prelude::*;
use dkkm::util::stats::{bench_repeats, bench_scale, mean_std, pm, Table};

fn main() {
    let scale = bench_scale();
    let train = ((3000.0 * scale) as usize).max(600);
    let repeats = bench_repeats().max(3);
    println!("== Fig.8: accuracy vs B, ours vs Sculley SGD, synthetic MNIST N={train} ==");
    println!("(paper: N=60000; DKKM_SCALE=20 for full size)\n");

    let bs = [1usize, 2, 4, 8, 16, 32];
    let mut table = Table::new(&["B", "mini-batch kernel k-means", "SGD k-means (Sculley)"]);
    let mut our_var = Vec::new();
    let mut sgd_var = Vec::new();
    for &b in &bs {
        let (mut ours, mut sgd) = (Vec::new(), Vec::new());
        for r in 0..repeats {
            let seed = 500 + r as u64;
            let rep = Experiment::on(DatasetSpec::Mnist { train, test: 0 })
                .clusters(10)
                .batches(b)
                .seed(seed)
                .build()
                .expect("build")
                .fit()
                .expect("run");
            ours.push(rep.train_accuracy * 100.0);

            // SGD consumes the same data volume: iterations scale with B
            // so both methods see the whole dataset once per comparison
            let (data, _) = build_dataset(&DatasetSpec::Mnist { train, test: 0 }, seed);
            let scfg = SgdConfig {
                c: 10,
                batch: (train / b).clamp(50, 1000),
                iterations: b.max(train / (train / b).clamp(50, 1000)),
                seed: 900 + r as u64,
            };
            let (labels, _) = sgd_kmeans(&data.x, &scfg);
            sgd.push(accuracy(&labels, &data.y) * 100.0);
        }
        let (om, ostd) = mean_std(&ours);
        let (sm, sstd) = mean_std(&sgd);
        our_var.push(ostd);
        sgd_var.push(sstd);
        table.row(&[b.to_string(), pm(om, ostd), pm(sm, sstd)]);
    }
    println!("{}", table.render());
    let our_mean_std = our_var.iter().sum::<f64>() / our_var.len() as f64;
    let sgd_mean_std = sgd_var.iter().sum::<f64>() / sgd_var.len() as f64;
    println!("mean run-to-run std: ours {our_mean_std:.2} vs SGD {sgd_mean_std:.2}");
    println!("shape check: ours highest at small B, gently degrading; SGD ~flat;");
    println!("our variance smaller (Fig.8).");
}
