//! Crate-wide error type (hand-rolled `Display`/`Error` impls keep the
//! crate dependency-free).
use std::fmt;

/// Errors surfaced by the dkkm library.
#[derive(Debug)]
pub enum Error {
    /// Invalid configuration or CLI arguments.
    Config(String),
    /// Shape/dimension mismatch in a linear-algebra or clustering op.
    Shape(String),
    /// PJRT runtime failure.
    Runtime(String),
    /// I/O failure.
    Io(std::io::Error),
    /// A distributed node failed (panic, deadline, or dropped collective).
    Node {
        /// Original rank of the failed node.
        rank: usize,
        /// Collective sequence number at which the failure surfaced.
        seq: u64,
        /// Human-readable cause.
        msg: String,
    },
    /// A run was interrupted (e.g. injected `interrupt:e` fault) after
    /// writing an epoch checkpoint; re-run with `resume` to continue.
    Interrupted {
        /// Epoch (batch index) at which the run stopped.
        epoch: usize,
    },
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Config(msg) => write!(f, "config error: {msg}"),
            Error::Shape(msg) => write!(f, "shape error: {msg}"),
            Error::Runtime(msg) => write!(f, "runtime error: {msg}"),
            Error::Io(e) => write!(f, "{e}"),
            Error::Node { rank, seq, msg } => {
                write!(f, "node error: rank {rank} failed at collective {seq}: {msg}")
            }
            Error::Interrupted { epoch } => {
                write!(f, "run interrupted at epoch {epoch} (resume from checkpoint to continue)")
            }
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::Io(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
