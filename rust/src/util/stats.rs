//! Timing + descriptive statistics + table formatting for the bench
//! harness (criterion substitute) and the experiment reports.
use std::time::Instant;

/// Online mean/variance accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Online {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Online {
    pub fn new() -> Self {
        Online { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1 denominator).
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Collect all samples (for percentiles) while keeping Online moments.
#[derive(Clone, Debug, Default)]
pub struct Samples {
    pub online: Online,
    xs: Vec<f64>,
}

impl Samples {
    pub fn new() -> Self {
        Samples { online: Online::new(), xs: Vec::new() }
    }

    pub fn push(&mut self, x: f64) {
        self.online.push(x);
        self.xs.push(x);
    }

    /// Linear-interpolated percentile, p in [0, 100].
    pub fn percentile(&self, p: f64) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        let mut sorted = self.xs.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = (p / 100.0) * (sorted.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }

    pub fn mean(&self) -> f64 {
        self.online.mean()
    }

    pub fn std(&self) -> f64 {
        self.online.std()
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }
}

/// Wall-clock timer returning seconds.
pub struct Timer(Instant);

impl Timer {
    pub fn start() -> Self {
        Timer(Instant::now())
    }

    pub fn elapsed_s(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_s() * 1e3
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    let s = t.elapsed_s();
    (out, s)
}

/// `mean ± std` with sensible precision.
pub fn pm(mean: f64, std: f64) -> String {
    if mean.abs() >= 100.0 {
        format!("{mean:.2} ± {std:.2}")
    } else if mean.abs() >= 1.0 {
        format!("{mean:.3} ± {std:.3}")
    } else {
        format!("{mean:.4} ± {std:.4}")
    }
}

/// Monospace table renderer for bench output (paper-style rows).
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        // widths in chars, not bytes: cells may contain "±"
        let cw = |s: &String| s.chars().count();
        let mut widths: Vec<usize> = self.headers.iter().map(cw).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(cw(c));
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for i in 0..ncols {
                let pad = widths[i] - cells[i].chars().count();
                line.push_str(&format!(" {}{} |", cells[i], " ".repeat(pad)));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_matches_naive() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut o = Online::new();
        for &x in &xs {
            o.push(x);
        }
        assert!((o.mean() - 5.0).abs() < 1e-12);
        let naive_var =
            xs.iter().map(|x| (x - 5.0) * (x - 5.0)).sum::<f64>() / (xs.len() - 1) as f64;
        assert!((o.var() - naive_var).abs() < 1e-12);
        assert_eq!(o.min(), 2.0);
        assert_eq!(o.max(), 9.0);
    }

    #[test]
    fn online_single_sample() {
        let mut o = Online::new();
        o.push(3.0);
        assert_eq!(o.mean(), 3.0);
        assert_eq!(o.std(), 0.0);
    }

    #[test]
    fn percentiles() {
        let mut s = Samples::new();
        for i in 1..=100 {
            s.push(i as f64);
        }
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-9);
        assert!((s.percentile(100.0) - 100.0).abs() < 1e-9);
        assert!((s.percentile(50.0) - 50.5).abs() < 1e-9);
        assert!((s.percentile(90.0) - 90.1).abs() < 1e-9);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["B", "Accuracy", "Time"]);
        t.row(&["1".into(), "86.47 ± 0.37".into(), "655.2".into()]);
        t.row(&["64".into(), "78.39 ± 0.95".into(), "9.5".into()]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        // compare char counts: cells may contain multi-byte "±"
        let w0 = lines[0].chars().count();
        assert!(lines.iter().all(|l| l.chars().count() == w0));
        assert!(r.contains("86.47"));
    }

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(t.elapsed_ms() >= 4.0);
    }
}

/// Mean and sample standard deviation of a slice.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    let mut o = Online::new();
    for &x in xs {
        o.push(x);
    }
    (o.mean(), o.std())
}

/// Bench-harness environment knobs: `DKKM_SCALE` multiplies workload
/// sizes (default 1.0 = the scaled-for-this-host defaults documented in
/// EXPERIMENTS.md), `DKKM_REPEATS` sets seeds per configuration.
pub fn bench_scale() -> f64 {
    std::env::var("DKKM_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1.0)
}

/// Number of repeated seeds per bench configuration.
pub fn bench_repeats() -> usize {
    std::env::var("DKKM_REPEATS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3)
}
