//! Whole-pipeline integration: runner-level experiments across dataset
//! families, offload on/off equivalence under the PJRT backend, failure
//! injection, and metric invariants end to end.
use dkkm::coordinator::runner::{build_dataset, run_experiment};
use dkkm::coordinator::{BackendChoice, DatasetSpec, RunConfig};
use dkkm::metrics::{accuracy, nmi};
use dkkm::util::rng::Rng;

fn base(spec: DatasetSpec) -> RunConfig {
    let mut cfg = RunConfig::new(spec);
    cfg.c = Some(4);
    cfg.b = 2;
    cfg.sigma_factor = 0.1;
    cfg
}

#[test]
fn every_dataset_family_runs() {
    // one cheap config per family; asserts basic report sanity
    let cases: Vec<RunConfig> = vec![
        base(DatasetSpec::Toy2d { per_cluster: 60 }),
        {
            let mut c = RunConfig::new(DatasetSpec::Mnist { train: 300, test: 60 });
            c.c = Some(10);
            c.b = 2;
            c
        },
        {
            let mut c = RunConfig::new(DatasetSpec::Rcv1 { n: 400, classes: 6, dim: 32 });
            c.c = Some(6);
            c.b = 2;
            c
        },
        {
            let mut c = RunConfig::new(DatasetSpec::NoisyMnist { base: 60, copies: 4 });
            c.c = Some(10);
            c.b = 2;
            c
        },
        {
            let mut c = RunConfig::new(DatasetSpec::Md { frames: 300 });
            c.c = Some(5);
            c.b = 2;
            c
        },
    ];
    for cfg in cases {
        let rep = run_experiment(&cfg)
            .unwrap_or_else(|e| panic!("{:?} failed: {e}", cfg.dataset));
        assert!(rep.seconds >= 0.0);
        assert!((0.0..=1.0).contains(&rep.train_accuracy), "{:?}", cfg.dataset);
        assert!((0.0..=1.0).contains(&rep.train_nmi));
        assert!(rep.result.labels.iter().all(|&u| u < rep.c_used));
    }
}

#[test]
fn offload_equals_inline_through_pjrt_backend() {
    let mut cfg = RunConfig::new(DatasetSpec::Mnist { train: 400, test: 0 });
    cfg.c = Some(10);
    cfg.b = 4;
    cfg.backend = BackendChoice::Pjrt;
    cfg.offload = false;
    let inline = run_experiment(&cfg).unwrap();
    cfg.offload = true;
    let offload = run_experiment(&cfg).unwrap();
    assert_eq!(inline.result.labels, offload.result.labels);
    assert_eq!(inline.result.medoids, offload.result.medoids);
    assert!(offload.result.overlap.is_some());
}

#[test]
fn pjrt_backend_quality_matches_native() {
    let mut cfg = RunConfig::new(DatasetSpec::Mnist { train: 500, test: 100 });
    cfg.c = Some(10);
    cfg.b = 2;
    let native = run_experiment(&cfg).unwrap();
    cfg.backend = BackendChoice::Pjrt;
    let pjrt = run_experiment(&cfg).unwrap();
    assert!(
        (native.train_accuracy - pjrt.train_accuracy).abs() < 0.05,
        "native {} vs pjrt {}",
        native.train_accuracy,
        pjrt.train_accuracy
    );
}

#[test]
fn invalid_configs_rejected() {
    let mut cfg = base(DatasetSpec::Toy2d { per_cluster: 40 });
    cfg.s = 0.0;
    assert!(run_experiment(&cfg).is_err());
    let mut cfg = base(DatasetSpec::Toy2d { per_cluster: 40 });
    cfg.b = 0;
    assert!(run_experiment(&cfg).is_err());
    let mut cfg = base(DatasetSpec::Toy2d { per_cluster: 40 });
    cfg.restarts = 0;
    assert!(run_experiment(&cfg).is_err());
}

#[test]
fn seeds_reproduce_exactly() {
    let cfg = base(DatasetSpec::Toy2d { per_cluster: 50 });
    let a = run_experiment(&cfg).unwrap();
    let b = run_experiment(&cfg).unwrap();
    assert_eq!(a.result.labels, b.result.labels);
    assert_eq!(a.train_accuracy, b.train_accuracy);
    let mut cfg2 = cfg.clone();
    cfg2.seed = 77;
    let c = run_experiment(&cfg2).unwrap();
    // different seed: almost surely different medoids
    assert!(
        c.result.medoids != a.result.medoids || c.result.labels != a.result.labels
    );
}

#[test]
fn metrics_are_permutation_invariant_end_to_end() {
    let cfg = base(DatasetSpec::Toy2d { per_cluster: 50 });
    let rep = run_experiment(&cfg).unwrap();
    let (train, _) = build_dataset(&cfg.dataset, cfg.seed);
    // permute cluster ids
    let perm = [2usize, 0, 3, 1];
    let permuted: Vec<usize> = rep.result.labels.iter().map(|&u| perm[u]).collect();
    assert!((accuracy(&permuted, &train.y) - rep.train_accuracy).abs() < 1e-12);
    assert!((nmi(&permuted, &train.y) - rep.train_nmi).abs() < 1e-9);
}

#[test]
fn b_sweep_time_decreases() {
    // Tab.1's cost claim as an invariant: more mini-batches => less work
    let mut times = Vec::new();
    for b in [1usize, 4, 8] {
        let mut cfg = RunConfig::new(DatasetSpec::Mnist { train: 800, test: 0 });
        cfg.c = Some(10);
        cfg.b = b;
        let mut rng = Rng::new(0);
        let _ = &mut rng;
        times.push(run_experiment(&cfg).unwrap().seconds);
    }
    assert!(
        times[0] > times[1] && times[1] > times[2],
        "time not decreasing in B: {times:?}"
    );
}
