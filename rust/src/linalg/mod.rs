//! Dense row-major matrices + the linear algebra the pipeline needs:
//! blocked pairwise squared distances, small matmuls for one-hot products,
//! symmetric eigendecomposition ([`jacobi_eigh`]) for the Nyström
//! landmark factorization, Kabsch/QCP RMSD for roto-translationally
//! invariant MD kernels, and the CPU-feature dispatch ([`simd`]) behind
//! the packed Gram micro-kernel.
mod eig;
mod mat;
mod pairwise;
mod rmsd;
pub mod simd;

pub use eig::{jacobi_eigh, EigH};
pub use mat::Mat;
pub use pairwise::{row_sq_norms, sq_dists_block, sq_dists_block_into, sq_dists_block_reference};
pub use rmsd::{centroid, kabsch_rmsd, qcp_rmsd, Frame};
pub use simd::SimdTier;
