//! Small self-contained substrates: error type, RNG, JSON, CLI, stats.
pub mod error;
pub mod cli;
pub mod json;
pub mod rng;
pub mod stats;
pub mod threadpool;
