//! Baseline algorithms the paper compares against:
//!
//! * [`lloyd`] — standard k-means (k-means++ init + Lloyd iterations),
//!   standing in for the scikit-learn baseline rows of Tab.1-2.
//! * [`sgd`] — Sculley's web-scale mini-batch SGD k-means [9], the
//!   comparison of Fig.8.
pub mod lloyd;
pub mod sgd;

pub use lloyd::{lloyd_kmeans, LloydResult};
pub use sgd::{sgd_kmeans, SgdConfig};
