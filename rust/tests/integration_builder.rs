//! Public-API contract tests for the staged builder, the engine
//! registry, and the session: everything a downstream caller relies on
//! reaches through `dkkm::prelude`.
use dkkm::coordinator::shared_pjrt;
use dkkm::prelude::*;

fn toy() -> Experiment {
    Experiment::on(DatasetSpec::Toy2d { per_cluster: 60 })
        .clusters(4)
        .batches(2)
        .sigma_factor(0.1)
}

#[test]
fn staged_builder_happy_path() {
    let session = toy().build().expect("build");
    let report = session.fit().expect("fit");
    assert_eq!(report.c_used, 4);
    assert_eq!(report.engine.requested, "native");
    assert_eq!(report.engine.used, "native");
    assert!(report.engine.fallback.is_none());
    assert!(report.train_accuracy > 0.5);
}

#[test]
fn session_exposes_materialized_state() {
    let session = toy().build().unwrap();
    assert_eq!(session.n(), 240);
    assert_eq!(session.gram().n(), 240);
    assert!(session.gamma() > 0.0);
    let train = session.train().expect("vector workload");
    assert_eq!(train.n(), 240);
    assert!(session.test().is_none());
    assert_eq!(session.truth().len(), 240);
    assert_eq!(session.config().c, Some(4));
}

#[test]
fn session_gram_source_is_usable_directly() {
    // algorithm-level drivers can run on the session's Gram source
    let session = toy().build().unwrap();
    let idx: Vec<usize> = (0..10).collect();
    let block = session.gram().block_mat(&idx, &idx);
    for i in 0..10 {
        assert!((block.at(i, i) - 1.0).abs() < 1e-6, "RBF diag");
        for j in 0..10 {
            assert!((block.at(i, j) - block.at(j, i)).abs() < 1e-5);
        }
    }
}

#[test]
fn elbow_scan_reuses_the_session() {
    let session = toy().auto_clusters().build().unwrap();
    let c = session.elbow(2, 8);
    assert!((2..=8).contains(&c), "elbow picked {c}");
    // and the fit at that C flows through the same session
    let report = session.fit_clusters(c).unwrap();
    assert_eq!(report.c_used, c);
}

#[test]
fn engine_names_round_trip_through_reports() {
    let sharded = toy().backend("sharded:3").build().unwrap();
    assert_eq!(sharded.engine().requested, "sharded:3");
    assert_eq!(sharded.engine().used, "sharded:3");
    let report = sharded.fit().unwrap();
    assert_eq!(report.engine.used, "sharded:3");
}

#[test]
fn build_failures_are_structured_config_errors() {
    let err = toy().backend("abacus").build().unwrap_err();
    assert!(matches!(err, Error::Config(_)), "wrong error kind: {err:?}");
    let err = toy().backend("sharded:2").offload(true).build().unwrap_err();
    assert!(matches!(err, Error::Config(_)), "wrong error kind: {err:?}");
    let err = toy().clusters(200).build().unwrap_err();
    assert!(matches!(err, Error::Config(_)), "wrong error kind: {err:?}");
}

#[test]
fn pjrt_fallback_is_recorded_not_silent() {
    // d=33 has no lowered rbf artifact, so the pjrt engine must degrade
    // to the native Gram path AND say so in the report. Skip when the
    // artifact manifest itself is absent (pjrt engine cannot construct).
    if shared_pjrt().is_err() {
        eprintln!("skipping: no artifact manifest (run `make artifacts`)");
        return;
    }
    let spec = DatasetSpec::Rcv1 { n: 200, classes: 4, dim: 33, storage: RcvStorage::Dense };
    let session = Experiment::on(spec)
        .clusters(4)
        .batches(2)
        .backend("pjrt")
        .build()
        .unwrap();
    assert_eq!(session.engine().requested, "pjrt");
    assert_eq!(session.engine().used, "native");
    let reason = session.engine().fallback.as_deref().expect("fallback reason");
    assert!(reason.contains("d=33"), "unhelpful reason: {reason}");
    // the run itself still succeeds, and the report carries provenance
    let report = session.fit().unwrap();
    assert_eq!(report.engine.used, "native");
    let j = report.to_json();
    let parsed = dkkm::util::json::Json::parse(&j.to_string()).unwrap();
    let engine = parsed.get("engine").expect("engine in report json");
    assert_eq!(engine.get("used").and_then(|v| v.as_str()), Some("native"));
    assert!(engine
        .get("fallback")
        .and_then(|v| v.as_str())
        .unwrap()
        .contains("d=33"));
}

#[test]
fn md_workload_is_not_a_fork() {
    // same builder, same fit(), no dedicated runner: the MD workload is
    // engine + RmsdGram composition
    let session = Experiment::on(DatasetSpec::Md { frames: 300 })
        .clusters(5)
        .batches(2)
        .build()
        .unwrap();
    let report = session.fit().unwrap();
    assert_eq!(report.c_used, 5);
    assert!(report.test_accuracy.is_none(), "MD has no held-out split");
    let (medoids, mat, macro_of) = session.medoid_rmsd_matrix(&report).unwrap();
    assert_eq!(medoids.len(), 5);
    assert_eq!(mat.rows(), 5);
    assert!(macro_of.iter().all(|&m| m < 3));
}

#[test]
fn sharded_md_composes() {
    // orthogonal axes: the distributed inner loop composes with the
    // RMSD Gram source, no special-casing anywhere
    let native = Experiment::on(DatasetSpec::Md { frames: 200 })
        .clusters(4)
        .batches(2)
        .build()
        .unwrap()
        .fit()
        .unwrap();
    let sharded = Experiment::on(DatasetSpec::Md { frames: 200 })
        .clusters(4)
        .batches(2)
        .backend("sharded:3")
        .build()
        .unwrap()
        .fit()
        .unwrap();
    assert_eq!(native.result.labels, sharded.result.labels);
    assert_eq!(native.result.medoids, sharded.result.medoids);
}
