//! Row-sharded inner loop over real node threads (paper §3.3, Fig.2).
//!
//! Each of the P node threads owns a contiguous slice of the mini-batch
//! kernel block — rows of a whole panel, tiles of a memory-budgeted
//! tiled panel (the tile is the shard work unit, so a spilled tile is
//! re-loaded by exactly the node that owns it); per iteration a node
//!
//!   1. computes the partial compactness `g` from its *landmark* rows,
//!   2. allreduce-sums `g` (the only float collective, C values),
//!   3. computes `f` and the argmin labels for its row slice,
//!   4. allgathers the label slices.
//!
//! The result is bit-identical to the serial backend (tested below),
//! which is exactly the paper's point: the distribution touches only the
//! schedule, not the math.
use crate::cluster::assign::{argmin_rows_into, masked_g, ClusterStats, Indicator};
use crate::cluster::minibatch::StepBackend;
use crate::kernels::GramView;
use crate::linalg::Mat;

use super::comm::Communicator;
use super::shard::row_shards;

/// Sharded implementation of one inner-loop iteration.
pub struct ShardedBackend {
    pub nodes: usize,
}

impl ShardedBackend {
    pub fn new(nodes: usize) -> ShardedBackend {
        assert!(nodes > 0);
        ShardedBackend { nodes }
    }
}

impl StepBackend for ShardedBackend {
    fn iterate(
        &self,
        k_nl: &GramView<'_>,
        k_ll: &Mat,
        lm_labels: &[usize],
        c: usize,
    ) -> (Vec<usize>, ClusterStats) {
        let n = k_nl.rows();
        let l = lm_labels.len();
        assert_eq!(k_nl.cols(), l, "K_nl columns must match landmark count");
        assert_eq!(k_ll.cols(), l, "K_ll must be L x L");
        let p = self.nodes.min(n.max(1));
        // whole panels shard by rows (historical layout); tiled panels
        // shard by tiles, which are contiguous row ranges, so each node
        // still owns a contiguous label slice for the allgather
        let tile_shards = match k_nl {
            GramView::Whole(_) => None,
            GramView::Tiled(_) => Some(row_shards(k_nl.n_tiles(), p)),
        };
        let row_shards_whole = row_shards(n, p);
        let lm_shards = row_shards(l, p);
        let comm = Communicator::new(p);

        // landmark counts are cheap and label-only: every node derives
        // them locally (the paper ships labels, not counts)
        let mut counts = vec![0usize; c];
        for &u in lm_labels {
            counts[u] += 1;
        }
        let inv: Vec<f32> = counts
            .iter()
            .map(|&s| if s > 0 { 1.0 / s as f32 } else { 0.0 })
            .collect();

        // the packed indicators are built once per iteration and shared
        // read-only by every node: the scaled one serves the f GEMMs,
        // the one-hot one the compactness quadratic form — both run
        // through the same dispatched micro-kernel as the serial path
        let ind = Indicator::scaled(lm_labels, &inv);
        let onehot = Indicator::onehot(lm_labels, c);

        let mut labels_out: Vec<usize> = vec![0; n];
        let mut g_out: Vec<f32> = vec![0.0; c];
        std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for rank in 0..p {
                let mut comm = comm.node();
                let view = *k_nl;
                let (llo, lhi) = lm_shards[rank];
                let tile_shards = tile_shards.as_deref();
                let row_shards_whole = &row_shards_whole;
                let inv = &inv;
                let counts = &counts;
                let ind = &ind;
                let onehot = &onehot;
                handles.push(scope.spawn(move || {
                    // --- partial g from this node's landmark rows:
                    // g_j = inv_j^2 sum_{m in shard, n: u_n = u_m = j} K_mn
                    // = inv_j^2 * (K_ll[shard] · M_onehot)[m][u_m] summed
                    let mut g_partial = vec![0.0f32; c];
                    if lhi > llo {
                        let mut t = vec![0.0f32; (lhi - llo) * c];
                        onehot.apply_rows(&k_ll.data()[llo * l..lhi * l], &mut t);
                        for (r, m) in (llo..lhi).enumerate() {
                            let um = lm_labels[m];
                            g_partial[um] += t[r * c + um] * inv[um] * inv[um];
                        }
                    }
                    // --- collective 1: allreduce(sum) of g
                    let g = comm.allreduce_sum(&g_partial);
                    let g_mask = masked_g(&g, counts);
                    // --- local f (one GEMM per slice/tile into a reused
                    //     scratch buffer) + argmin over this node's rows
                    let scratch_rows = match (&view, tile_shards) {
                        (GramView::Whole(_), _) => {
                            let (lo, hi) = row_shards_whole[rank];
                            hi - lo
                        }
                        (GramView::Tiled(_), _) => view.max_tile_rows(),
                    };
                    let mut scratch = vec![0.0f32; scratch_rows * c];
                    let mut local_labels = Vec::new();
                    let lo = match (&view, tile_shards) {
                        (GramView::Whole(mat), _) => {
                            let (lo, hi) = row_shards_whole[rank];
                            if hi > lo {
                                let f = &mut scratch[..(hi - lo) * c];
                                ind.apply_rows(&mat.data()[lo * l..hi * l], f);
                                argmin_rows_into(f, c, &g_mask, &mut local_labels);
                            }
                            lo
                        }
                        (GramView::Tiled(_), Some(shards)) => {
                            let (tlo, thi) = shards[rank];
                            if thi > tlo {
                                for t in tlo..thi {
                                    let (rlo, rhi) = view.tile_range(t);
                                    let tile = view.tile(t);
                                    let f = &mut scratch[..(rhi - rlo) * c];
                                    ind.apply_rows(tile.mat().data(), f);
                                    argmin_rows_into(f, c, &g_mask, &mut local_labels);
                                }
                                view.tile_range(tlo).0
                            } else {
                                n
                            }
                        }
                        (GramView::Tiled(_), None) => unreachable!("tile shards computed above"),
                    };
                    // --- collective 2: allgather of label slices
                    let all = comm.allgather_usize(lo, n, &local_labels);
                    (all, g)
                }));
            }
            let mut results: Vec<(Vec<usize>, Vec<f32>)> =
                handles.into_iter().map(|h| h.join().unwrap()).collect();
            // every node received identical vectors; take rank 0's
            let (labels, g) = results.swap_remove(0);
            labels_out = labels;
            g_out = g;
        });

        let stats = ClusterStats { counts, inv, g: g_out };
        (labels_out, stats)
    }

    fn name(&self) -> &'static str {
        "sharded"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::assign;
    use crate::cluster::minibatch::{MiniBatchConfig, MiniBatchKernelKMeans, NativeBackend};
    use crate::data::toy2d;
    use crate::kernels::{GramSource, KernelFn, VecGram};
    use crate::util::rng::Rng;

    fn random_setup(seed: u64, n: usize, l: usize, c: usize) -> (Mat, Mat, Vec<usize>) {
        let mut rng = Rng::new(seed);
        let x = Mat::from_fn(n.max(l), 3, |_, _| rng.normal32(0.0, 2.0));
        let g = VecGram::new(x, KernelFn::Rbf { gamma: 0.3 }, 2);
        let rows: Vec<usize> = (0..n).collect();
        let lms: Vec<usize> = (0..l).collect();
        let k_nl = g.block_mat(&rows, &lms);
        let k_ll = g.block_mat(&lms, &lms);
        let labels: Vec<usize> = (0..l).map(|_| rng.below(c)).collect();
        (k_nl, k_ll, labels)
    }

    #[test]
    fn matches_serial_for_any_p_property() {
        // the core distribution invariant: identical labels AND g for
        // every node count, including p > rows
        let (k_nl, k_ll, lm_labels) = random_setup(0, 37, 19, 5);
        let (want_labels, want_stats) =
            assign::inner_iteration(&k_nl, &k_ll, &lm_labels, 5);
        for p in [1usize, 2, 3, 4, 8, 16, 64] {
            let backend = ShardedBackend::new(p);
            let (labels, stats) = backend.iterate_mat(&k_nl, &k_ll, &lm_labels, 5);
            assert_eq!(labels, want_labels, "labels diverge at p={p}");
            for j in 0..5 {
                assert!(
                    (stats.g[j] - want_stats.g[j]).abs() < 1e-4,
                    "g[{j}] diverges at p={p}: {} vs {}",
                    stats.g[j],
                    want_stats.g[j]
                );
            }
            assert_eq!(stats.counts, want_stats.counts);
        }
    }

    #[test]
    fn full_minibatch_run_matches_native() {
        let mut rng = Rng::new(1);
        let d = toy2d(&mut rng, 60);
        let g = VecGram::new(d.x, KernelFn::Rbf { gamma: 20.0 }, 2);
        let cfg = MiniBatchConfig::new(4, 3);
        let native = MiniBatchKernelKMeans::new(cfg.clone(), &NativeBackend).run(&g);
        let backend = ShardedBackend::new(4);
        let sharded = MiniBatchKernelKMeans::new(cfg, &backend).run(&g);
        assert_eq!(native.labels, sharded.labels);
        assert_eq!(native.medoids, sharded.medoids);
        assert_eq!(native.counts, sharded.counts);
    }

    #[test]
    fn tiled_minibatch_run_matches_native_whole() {
        // tiles as shard work units: sharded + memory budget must equal
        // the serial whole-panel reference bit for bit
        let mut rng = Rng::new(2);
        let d = toy2d(&mut rng, 60);
        let g = VecGram::new(d.x, KernelFn::Rbf { gamma: 20.0 }, 2);
        let cfg = MiniBatchConfig::new(4, 2);
        let reference = MiniBatchKernelKMeans::new(cfg.clone(), &NativeBackend).run(&g);
        let mut budget_cfg = cfg;
        budget_cfg.memory_budget = Some(16 * 1024); // 120x120 panel = 56 KiB
        let backend = ShardedBackend::new(3);
        let sharded = MiniBatchKernelKMeans::new(budget_cfg, &backend).run(&g);
        assert_eq!(reference.labels, sharded.labels);
        assert_eq!(reference.medoids, sharded.medoids);
        assert_eq!(reference.counts, sharded.counts);
        assert!(sharded.pipeline.tiles > 2, "{:?}", sharded.pipeline);
        assert!(sharded.pipeline.peak_resident_bytes <= 16 * 1024);
    }

    #[test]
    fn empty_clusters_handled() {
        let (k_nl, k_ll, mut lm_labels) = random_setup(2, 20, 10, 6);
        lm_labels.iter_mut().for_each(|u| *u %= 2);
        let backend = ShardedBackend::new(3);
        let (labels, stats) = backend.iterate_mat(&k_nl, &k_ll, &lm_labels, 6);
        assert!(labels.iter().all(|&u| u < 2));
        assert_eq!(&stats.counts[2..], &[0, 0, 0, 0]);
    }
}
