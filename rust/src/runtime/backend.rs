//! PJRT inner-iteration backend: one fused artifact call per row chunk
//! (`inner_n1024_l{256,1024}_c32` — compactness + similarity + argmin in
//! a single XLA executable, the L2 graph built from the L1 Pallas
//! kernels).
//!
//! Padding contract (matching python/compile/aot.py):
//! * rows are processed in chunks of N_TILE = 1024, the last chunk
//!   zero-padded (its labels are discarded);
//! * landmarks pad to the artifact's L with all-zero one-hot rows, so
//!   padded landmarks belong to no cluster and contribute nothing to
//!   f or g;
//! * clusters pad to C_PAD = 32 with `valid = 0` columns, masked to +inf
//!   distance inside the kernel.
//!
//! Landmark sets above 1024 fall back to the native path (a chunked
//! fpartial/argmin route exists in the artifact set but the native sweep
//! is faster than the RPC overhead at that size on CPU).
use std::sync::Arc;

use crate::cluster::assign::{self, ClusterStats};
use crate::cluster::minibatch::StepBackend;
use crate::kernels::GramView;
use crate::linalg::Mat;
use crate::util::error::Result;

use super::client::{PjrtRuntime, Tensor};

const N_TILE: usize = 1024;
const C_PAD: usize = 32;

/// StepBackend over the fused PJRT artifact.
pub struct PjrtBackend {
    runtime: Arc<PjrtRuntime>,
}

impl PjrtBackend {
    pub fn new(runtime: Arc<PjrtRuntime>) -> PjrtBackend {
        PjrtBackend { runtime }
    }

    fn iterate_pjrt(
        &self,
        k_nl: &GramView<'_>,
        k_ll: &Mat,
        lm_labels: &[usize],
        c: usize,
    ) -> Result<Option<(Vec<usize>, ClusterStats)>> {
        let l = lm_labels.len();
        let Some(entry) = self.runtime.manifest().inner_for(l) else {
            return Ok(None); // too many landmarks for the lowered variants
        };
        if c > C_PAD {
            return Ok(None);
        }
        let l_pad = entry.param("l")?;
        let name = entry.name.clone();

        // --- cluster-state operands, shared across chunks
        let mut counts = vec![0usize; c];
        for &u in lm_labels {
            counts[u] += 1;
        }
        let mut onehot = Mat::zeros(l_pad, C_PAD);
        for (m, &u) in lm_labels.iter().enumerate() {
            onehot.set(m, u, 1.0);
        }
        let mut inv = vec![0.0f32; C_PAD];
        let mut valid = vec![0.0f32; C_PAD];
        for j in 0..c {
            if counts[j] > 0 {
                inv[j] = 1.0 / counts[j] as f32;
                valid[j] = 1.0;
            }
        }
        let kll_pad = k_ll.padded(l_pad, l_pad);

        let n = k_nl.rows();
        let mut labels = Vec::with_capacity(n);
        let mut g_out = vec![0.0f32; c];
        let mut first_chunk = true;
        // tile-wise sweep: each view tile is chunked to the artifact's
        // fixed N_TILE rows; per-row results are independent of the
        // chunk boundaries, so tiled and whole panels agree
        for t in 0..k_nl.n_tiles() {
            let tile = k_nl.tile(t)?;
            let m = tile.mat();
            for lo in (0..m.rows()).step_by(N_TILE) {
                let hi = (lo + N_TILE).min(m.rows());
                let chunk = m.row_slice(lo, hi).padded(N_TILE, l_pad);
                let outputs = self.runtime.execute(
                    &name,
                    vec![
                        Tensor::from_mat(&chunk),
                        Tensor::from_mat(&kll_pad),
                        Tensor::from_mat(&onehot),
                        Tensor::row(inv.clone()),
                        Tensor::row(valid.clone()),
                    ],
                )?;
                let chunk_labels = outputs[0].i32_data()?;
                labels.extend(chunk_labels[..hi - lo].iter().map(|&v| v as usize));
                if first_chunk {
                    let g = outputs[1].f32_data()?;
                    g_out.copy_from_slice(&g[..c]);
                    first_chunk = false;
                }
            }
        }
        let inv_c: Vec<f32> = inv[..c].to_vec();
        Ok(Some((labels, ClusterStats { counts, inv: inv_c, g: g_out })))
    }
}

impl StepBackend for PjrtBackend {
    fn iterate(
        &self,
        k_nl: &GramView<'_>,
        k_ll: &Mat,
        lm_labels: &[usize],
        c: usize,
    ) -> Result<(Vec<usize>, ClusterStats)> {
        match self.iterate_pjrt(k_nl, k_ll, lm_labels, c)? {
            Some(result) => Ok(result),
            // graceful fallback: shapes outside the lowered variants run
            // natively (same math, tested for parity)
            None => assign::inner_iteration_view(k_nl, k_ll, lm_labels, c),
        }
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{GramSource, KernelFn, VecGram};
    use crate::runtime::client::tests::try_shared_runtime;
    use crate::util::rng::Rng;

    fn setup(seed: u64, n: usize, l: usize, c: usize) -> (Mat, Mat, Vec<usize>) {
        let mut rng = Rng::new(seed);
        let x = Mat::from_fn(n.max(l), 5, |_, _| rng.normal32(0.0, 2.0));
        let g = VecGram::new(x, KernelFn::Rbf { gamma: 0.2 }, 2);
        let rows: Vec<usize> = (0..n).collect();
        let lms: Vec<usize> = (0..l).collect();
        let k_nl = g.block_mat(&rows, &lms);
        let k_ll = g.block_mat(&lms, &lms);
        let labels: Vec<usize> = (0..l).map(|_| rng.below(c)).collect();
        (k_nl, k_ll, labels)
    }

    #[test]
    fn matches_native_small() {
        let (k_nl, k_ll, lm_labels) = setup(0, 500, 100, 7);
        let (want, want_stats) = assign::inner_iteration(&k_nl, &k_ll, &lm_labels, 7);
        let Some(rt) = try_shared_runtime() else {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        };
        let backend = PjrtBackend::new(rt);
        let (got, stats) = backend.iterate_mat(&k_nl, &k_ll, &lm_labels, 7).unwrap();
        assert_eq!(got, want);
        for j in 0..7 {
            assert!(
                (stats.g[j] - want_stats.g[j]).abs() < 2e-4,
                "g[{j}]: {} vs {}",
                stats.g[j],
                want_stats.g[j]
            );
        }
        assert_eq!(stats.counts, want_stats.counts);
    }

    #[test]
    fn matches_native_multi_chunk_and_l1024() {
        // n > N_TILE forces chunking; l > 256 forces the l1024 variant
        let (k_nl, k_ll, lm_labels) = setup(1, 1500, 400, 10);
        let (want, _) = assign::inner_iteration(&k_nl, &k_ll, &lm_labels, 10);
        let Some(rt) = try_shared_runtime() else {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        };
        let backend = PjrtBackend::new(rt);
        let (got, _) = backend.iterate_mat(&k_nl, &k_ll, &lm_labels, 10).unwrap();
        let diff = got.iter().zip(&want).filter(|(a, b)| a != b).count();
        assert_eq!(diff, 0, "{diff} label mismatches");
    }

    #[test]
    fn empty_clusters_masked() {
        let (k_nl, k_ll, mut lm_labels) = setup(2, 300, 80, 8);
        lm_labels.iter_mut().for_each(|u| *u %= 3);
        let Some(rt) = try_shared_runtime() else {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        };
        let backend = PjrtBackend::new(rt);
        let (labels, stats) = backend.iterate_mat(&k_nl, &k_ll, &lm_labels, 8).unwrap();
        assert!(labels.iter().all(|&u| u < 3));
        assert_eq!(&stats.counts[3..], &[0; 5]);
    }

    #[test]
    fn oversized_landmarks_fall_back_to_native() {
        let (k_nl, k_ll, lm_labels) = setup(3, 100, 1100, 4);
        let (want, _) = assign::inner_iteration(&k_nl, &k_ll, &lm_labels, 4);
        let Some(rt) = try_shared_runtime() else {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        };
        let backend = PjrtBackend::new(rt);
        let (got, _) = backend.iterate_mat(&k_nl, &k_ll, &lm_labels, 4).unwrap();
        assert_eq!(got, want);
    }
}
