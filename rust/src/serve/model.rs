//! The servable model: fixed medoid features + packed panels + the one
//! shared batched-assign helper every query path routes through.
//!
//! Once a fit finishes, assignment is rank-C linear algebra against the
//! medoid set (the embed-and-conquer observation: landmarks fixed →
//! queries are cheap GEMMs). A [`ServeModel`] freezes exactly that:
//! the medoid feature rows (dense or CSR), their [`PackedPanel`] for
//! the micro-kernel, the per-medoid norms/diagonal, and the
//! [`ClusterStats`] over the medoid landmark set (identity labels —
//! each medoid is its own cluster, so `g_j = K(m_j, m_j)` and the
//! Eq.8 assignment `argmin_j g_j − 2 K(x, m_j)` is the same branchless
//! row-argmin the inner loop uses).
//!
//! **Bit-identity discipline.** Every consumer — `Session`'s held-out
//! metrics, the serve loop's micro-batches, a model reloaded from a
//! snapshot — builds through [`ServeModel::from_features`] and assigns
//! through [`ServeModel::assign_rows`]. The micro-kernel guarantees
//! per-row results independent of row grouping, so any micro-batch
//! partition (1-row, 8-row, 64-row) of the same model yields identical
//! labels, and a reloaded model with bit-identical features yields
//! labels identical to the fitting session's.
use crate::cluster::assign::{argmin_rows_into, ClusterStats};
use crate::data::CsrMat;
use crate::kernels::microkernel::{self, PackedPanel};
use crate::kernels::KernelFn;
use crate::linalg::{row_sq_norms, simd, Mat};
use crate::util::error::{Error, Result};

/// Default micro-batch row count for query coalescing: a multiple of
/// the micro-kernel's register block that amortizes dispatch without
/// hurting tail latency.
pub const MICRO_BATCH: usize = 64;

/// A block of feature rows in either Gram operand storage. Used for the
/// model's medoid features, for appended refresh data, and for query
/// payloads — one enum, so dense and CSR route through the same helper.
#[derive(Clone, Debug)]
pub enum RowBlock {
    Dense(Mat),
    Csr(CsrMat),
}

impl RowBlock {
    pub fn rows(&self) -> usize {
        match self {
            RowBlock::Dense(m) => m.rows(),
            RowBlock::Csr(x) => x.rows(),
        }
    }

    /// Feature dimension (Gram operand depth).
    pub fn dim(&self) -> usize {
        match self {
            RowBlock::Dense(m) => m.cols(),
            RowBlock::Csr(x) => x.cols(),
        }
    }

    /// Storage kind name, matching `Session::storage()`.
    pub fn storage(&self) -> &'static str {
        match self {
            RowBlock::Dense(_) => "dense",
            RowBlock::Csr(_) => "csr",
        }
    }
}

/// Identity of the fit a model came from. Persisted with every
/// snapshot and checked on reload (like the epoch-checkpoint
/// fingerprint): a silent mismatch would serve another run's medoids.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SnapshotFingerprint {
    /// Canonical dataset spec string (`toy2d:200`, `rcv1:...:sparse`).
    pub dataset: String,
    pub seed: u64,
    pub b: usize,
    pub c: usize,
    /// Training set size the medoid indices refer to.
    pub n: usize,
    /// Gram operand storage (`dense` | `csr`).
    pub storage: String,
    /// Engine that ran the fit (`native`, `sharded:<p>`, ...).
    pub engine: String,
}

impl SnapshotFingerprint {
    /// Fingerprint for a transient model never meant for persistence
    /// (the `assign_test_set` path builds one per call).
    pub fn adhoc(storage: &str, c: usize, n: usize) -> SnapshotFingerprint {
        SnapshotFingerprint {
            dataset: "adhoc".into(),
            seed: 0,
            b: 0,
            c,
            n,
            storage: storage.into(),
            engine: "adhoc".into(),
        }
    }

    fn render(&self) -> String {
        format!(
            "dataset={} seed={:016x} B={} C={} N={} storage={} engine={}",
            self.dataset, self.seed, self.b, self.c, self.n, self.storage, self.engine
        )
    }

    /// Reject a snapshot written by a different fit; the error names
    /// both fingerprints so the mismatch is diagnosable.
    pub fn check(&self, expect: &SnapshotFingerprint) -> Result<()> {
        if self != expect {
            return Err(Error::Config(format!(
                "snapshot fingerprint mismatch: file has [{}], expected [{}]; \
                 refit or point at the matching snapshot",
                self.render(),
                expect.render()
            )));
        }
        Ok(())
    }
}

/// A fitted, immutable, servable model (see module docs). Cheap to
/// share read-only across worker threads behind an `Arc`: the packed
/// panel is packed once at construction and never mutated.
#[derive(Clone, Debug)]
pub struct ServeModel {
    kernel: KernelFn,
    /// Medoid feature rows, in cluster order (row `j` = cluster `j`).
    features: RowBlock,
    /// Medoid columns packed for the micro-kernel GEMM.
    packed: PackedPanel,
    /// `‖m_j‖²` in packed (= cluster) order.
    med_norms: Vec<f32>,
    /// `ClusterStats` over the medoid landmark set with identity
    /// labels: counts all 1, `g_j = K(m_j, m_j)`.
    stats: ClusterStats,
    /// `stats.masked_g()` cached for the assignment argmin.
    g: Vec<f32>,
    /// Global cluster weights `|w_j|` from the fit (Eq.11 merge state,
    /// carried so background refresh can continue the convex merge).
    weights: Vec<usize>,
    /// Medoid sample indices in the fitting (or refresh) working set —
    /// provenance only, never dereferenced by the serve path.
    medoids: Vec<usize>,
    fingerprint: SnapshotFingerprint,
}

impl ServeModel {
    /// Build a model from medoid features. This is the **single**
    /// construction path — the fitting session, the snapshot reader and
    /// the refresh epoch all come through here, so every derived
    /// quantity (norms, packed panel, diagonal, stats) is computed by
    /// one code path and reloads stay bit-identical.
    pub fn from_features(
        features: RowBlock,
        kernel: KernelFn,
        weights: Vec<usize>,
        medoids: Vec<usize>,
        fingerprint: SnapshotFingerprint,
    ) -> Result<ServeModel> {
        let c = features.rows();
        if c == 0 {
            return Err(Error::Config("a servable model needs at least one medoid".into()));
        }
        if features.dim() == 0 {
            return Err(Error::Shape("medoid features have zero dimension".into()));
        }
        if weights.len() != c || medoids.len() != c {
            return Err(Error::Shape(format!(
                "medoid metadata mismatch: {c} feature rows, {} weights, {} indices",
                weights.len(),
                medoids.len()
            )));
        }
        let cols: Vec<usize> = (0..c).collect();
        let (packed, med_norms) = match &features {
            RowBlock::Dense(m) => (PackedPanel::pack_gather(m, &cols), row_sq_norms(m)),
            RowBlock::Csr(x) => (PackedPanel::pack_gather_csr(x, &cols), x.sq_norms().to_vec()),
        };
        // exact diagonal from the cached norm (d² = 0), mirroring
        // `VecGram::diag` — never the GEMM's norm-reconstructed d²
        let med_diag: Vec<f32> =
            med_norms.iter().map(|&nn| kernel.from_parts(0.0, nn)).collect();
        // ClusterStats over the medoid landmark set with identity
        // labels: only K_mm's diagonal enters g, so the off-diagonal
        // can stay zero without changing any derived value
        let identity: Vec<usize> = (0..c).collect();
        let k_mm = Mat::from_fn(c, c, |i, j| if i == j { med_diag[i] } else { 0.0 });
        let stats = ClusterStats::compute(&k_mm, &identity, c);
        let g = stats.masked_g();
        Ok(ServeModel {
            kernel,
            features,
            packed,
            med_norms,
            stats,
            g,
            weights,
            medoids,
            fingerprint,
        })
    }

    pub fn c(&self) -> usize {
        self.features.rows()
    }

    /// Feature dimension queries must match.
    pub fn dim(&self) -> usize {
        self.features.dim()
    }

    pub fn storage(&self) -> &'static str {
        self.features.storage()
    }

    pub fn kernel(&self) -> KernelFn {
        self.kernel
    }

    pub fn features(&self) -> &RowBlock {
        &self.features
    }

    pub fn stats(&self) -> &ClusterStats {
        &self.stats
    }

    pub fn weights(&self) -> &[usize] {
        &self.weights
    }

    pub fn medoids(&self) -> &[usize] {
        &self.medoids
    }

    pub fn med_norms(&self) -> &[f32] {
        &self.med_norms
    }

    pub fn fingerprint(&self) -> &SnapshotFingerprint {
        &self.fingerprint
    }

    /// Resident bytes of the packed medoid panel (reporting only).
    pub fn packed_bytes(&self) -> usize {
        self.packed.n_panels() * self.packed.depth() * microkernel::NR
            * std::mem::size_of::<f32>()
    }

    /// Assign a set of dense rows (by index into `x`, with `xn` squared
    /// norms indexed by sample id) and push labels onto `out`. One
    /// fused Gram fill against the packed medoid panel, then the shared
    /// branchless argmin of `g_j − 2 K(x, m_j)`.
    pub fn assign_dense_rows(
        &self,
        x: &Mat,
        rows: &[usize],
        xn: &[f32],
        out: &mut Vec<usize>,
    ) -> Result<()> {
        if x.cols() != self.packed.depth() {
            return Err(Error::Shape(format!(
                "query dimension {} does not match the model's {}",
                x.cols(),
                self.packed.depth()
            )));
        }
        let c = self.c();
        let mut k = vec![0.0f32; rows.len() * c];
        microkernel::fill_gram_rows(
            simd::active_tier(),
            x,
            rows,
            &self.packed,
            xn,
            &self.med_norms,
            self.kernel,
            &mut k,
        );
        argmin_rows_into(&k, c, &self.g, out);
        Ok(())
    }

    /// CSR twin of [`ServeModel::assign_dense_rows`], sharing the same
    /// packed panel, epilogue and argmin.
    pub fn assign_csr_rows(
        &self,
        x: &CsrMat,
        rows: &[usize],
        out: &mut Vec<usize>,
    ) -> Result<()> {
        if x.cols() != self.packed.depth() {
            return Err(Error::Shape(format!(
                "query dimension {} does not match the model's {}",
                x.cols(),
                self.packed.depth()
            )));
        }
        let c = self.c();
        let mut k = vec![0.0f32; rows.len() * c];
        microkernel::fill_gram_rows_csr(
            simd::active_tier(),
            x,
            rows,
            &self.packed,
            x.sq_norms(),
            &self.med_norms,
            self.kernel,
            &mut k,
        );
        argmin_rows_into(&k, c, &self.g, out);
        Ok(())
    }

    /// Assign every row of a dense matrix, micro-batched at
    /// [`MICRO_BATCH`] rows (bit-identical to any other chunking).
    pub fn assign_dense(&self, x: &Mat) -> Result<Vec<usize>> {
        let xn = row_sq_norms(x);
        let all: Vec<usize> = (0..x.rows()).collect();
        let mut out = Vec::with_capacity(x.rows());
        for chunk in all.chunks(MICRO_BATCH) {
            self.assign_dense_rows(x, chunk, &xn, &mut out)?;
        }
        Ok(out)
    }

    /// Assign every row of a CSR matrix (micro-batched as above).
    pub fn assign_csr(&self, x: &CsrMat) -> Result<Vec<usize>> {
        let all: Vec<usize> = (0..x.rows()).collect();
        let mut out = Vec::with_capacity(x.rows());
        for chunk in all.chunks(MICRO_BATCH) {
            self.assign_csr_rows(x, chunk, &mut out)?;
        }
        Ok(out)
    }

    /// The shared batched-assign helper: dense and CSR query blocks
    /// through one entry point (the serve loop and the CLI route here).
    pub fn assign_rows(&self, rows: &RowBlock) -> Result<Vec<usize>> {
        match rows {
            RowBlock::Dense(m) => self.assign_dense(m),
            RowBlock::Csr(x) => self.assign_csr(x),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn toy_model(seed: u64, c: usize, d: usize) -> (ServeModel, Mat) {
        let mut rng = Rng::new(seed);
        let x = Mat::from_fn(40, d, |_, _| rng.normal32(0.0, 2.0));
        let medoids: Vec<usize> = (0..c).map(|j| j * 3).collect();
        let model = ServeModel::from_features(
            RowBlock::Dense(x.gather(&medoids)),
            KernelFn::Rbf { gamma: 0.3 },
            vec![1; c],
            medoids,
            SnapshotFingerprint::adhoc("dense", c, 40),
        )
        .unwrap();
        (model, x)
    }

    #[test]
    fn identity_stats_are_unit_counts_and_rbf_diag() {
        let (model, _) = toy_model(7, 5, 6);
        assert_eq!(model.stats().counts, vec![1; 5]);
        for &g in &model.stats().g {
            assert_eq!(g, 1.0, "RBF diagonal must be exactly 1");
        }
    }

    #[test]
    fn chunked_assign_matches_whole_assign() {
        let (model, x) = toy_model(11, 4, 6);
        let whole = model.assign_dense(&x).unwrap();
        let xn = row_sq_norms(&x);
        let all: Vec<usize> = (0..x.rows()).collect();
        for chunk_size in [1usize, 3, 8, 17] {
            let mut labels = Vec::new();
            for chunk in all.chunks(chunk_size) {
                model.assign_dense_rows(&x, chunk, &xn, &mut labels).unwrap();
            }
            assert_eq!(whole, labels, "chunk size {chunk_size} diverged");
        }
    }

    #[test]
    fn csr_and_dense_views_of_same_data_agree() {
        let mut rng = Rng::new(3);
        // sparse-ish data with exact zero runs
        let x = Mat::from_fn(30, 8, |_, _| {
            if rng.below(4) == 0 {
                rng.normal32(0.0, 1.0)
            } else {
                0.0
            }
        });
        let medoids = vec![0usize, 5, 9];
        let dense = ServeModel::from_features(
            RowBlock::Dense(x.gather(&medoids)),
            KernelFn::Rbf { gamma: 0.5 },
            vec![1; 3],
            medoids.clone(),
            SnapshotFingerprint::adhoc("dense", 3, 30),
        )
        .unwrap();
        let xc = CsrMat::from_dense(&x);
        let csr = ServeModel::from_features(
            RowBlock::Csr(xc.gather(&medoids)),
            KernelFn::Rbf { gamma: 0.5 },
            vec![1; 3],
            medoids,
            SnapshotFingerprint::adhoc("csr", 3, 30),
        )
        .unwrap();
        let a = dense.assign_dense(&x).unwrap();
        let b = csr.assign_csr(&xc).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn dimension_mismatch_is_a_shape_error() {
        let (model, _) = toy_model(5, 3, 6);
        let bad = Mat::zeros(4, 7);
        assert!(model.assign_dense(&bad).is_err());
    }

    #[test]
    fn fingerprint_mismatch_names_both_sides() {
        let a = SnapshotFingerprint::adhoc("dense", 3, 40);
        let mut b = a.clone();
        b.seed = 9;
        let err = a.check(&b).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("fingerprint mismatch"), "{msg}");
        assert!(msg.contains("seed=0000000000000009"), "{msg}");
    }

    #[test]
    fn empty_model_is_rejected() {
        let err = ServeModel::from_features(
            RowBlock::Dense(Mat::zeros(0, 4)),
            KernelFn::Linear,
            vec![],
            vec![],
            SnapshotFingerprint::adhoc("dense", 0, 0),
        );
        assert!(err.is_err());
    }
}
