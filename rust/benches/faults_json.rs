//! bench-json harness: fault-injection and recovery timings.
//!
//! Runs the sharded clustering workload under each fault class — clean
//! baseline, node death at a collective, a dropped straggler past the
//! deadline — across node counts, plus the checkpoint-write overhead and
//! interrupt→resume cost of the epoch checkpoint path, and emits
//! `BENCH_faults.json` (override the path with `DKKM_BENCH_OUT`). Every
//! faulted run is equivalence-asserted against the fault-free serial
//! reference, so the bench doubles as a smoke test: recovery must change
//! the timings, never the labels.
//!
//!     cargo bench --bench faults_json
//!
//! Knobs: `DKKM_SCALE` multiplies N, `DKKM_REPEATS` sets seeds per
//! configuration.
use std::sync::Arc;

use dkkm::cluster::minibatch::{MiniBatchConfig, MiniBatchKernelKMeans, NativeBackend};
use dkkm::coordinator::{build_dataset, faults_json, gamma_for, DatasetSpec};
use dkkm::distributed::{FaultPlan, FaultReport, FaultSession, ShardedBackend};
use dkkm::kernels::{KernelFn, VecGram};
use dkkm::util::json::Json;
use dkkm::util::stats::{bench_repeats, bench_scale, mean_std, Table, Timer};

fn session(spec: &str) -> Arc<FaultSession> {
    Arc::new(FaultSession::new(FaultPlan::parse(spec).expect("fault spec")))
}

fn main() {
    let n = ((2_000.0 * bench_scale()) as usize).max(400);
    let b = 4usize;
    let c = 10usize;
    let repeats = bench_repeats();
    println!("== fault-tolerance bench: synthetic MNIST N={n}, B={b}, C={c}, {repeats} seeds ==\n");

    let (data, _) = build_dataset(&DatasetSpec::Mnist { train: n, test: 0 }, 23);
    let gamma = gamma_for(&data, 4.0, 23);
    let source = VecGram::new(data.x.clone(), KernelFn::Rbf { gamma }, 1);
    let cfg = MiniBatchConfig::new(c, b);

    let t = Timer::start();
    let reference = MiniBatchKernelKMeans::new(cfg.clone(), &NativeBackend).run(&source).unwrap();
    let native_s = t.elapsed_s();

    // clean baseline vs node death vs straggler dropped at the deadline
    let scenarios: Vec<(&'static str, Option<&'static str>)> = vec![
        ("clean", None),
        ("kill", Some("kill:1@0")),
        ("timeout", Some("delay:1@0:80; deadline:25")),
    ];

    let mut table = Table::new(&["p", "scenario", "seconds", "recovery s", "re-shards"]);
    let mut rows = Vec::new();
    for p in [2usize, 4, 8] {
        for (name, spec) in &scenarios {
            let mut seconds = Vec::with_capacity(repeats);
            let mut recovery = Vec::with_capacity(repeats);
            let mut last = FaultReport::default();
            for _ in 0..repeats {
                // fresh session per repeat: one-shot injections re-arm
                let faults = match spec {
                    Some(s) => session(s),
                    None => FaultSession::clean(),
                };
                let backend = ShardedBackend::new(p).with_faults(faults.clone());
                let t = Timer::start();
                let res = MiniBatchKernelKMeans::new(cfg.clone(), &backend).run(&source).unwrap();
                seconds.push(t.elapsed_s());
                assert_eq!(
                    reference.labels,
                    res.labels,
                    "{name} diverged from the fault-free reference at p={p}"
                );
                last = faults.report();
                recovery.push(last.recovery_seconds);
            }
            let (sm, ss) = mean_std(&seconds);
            let (rm, _) = mean_std(&recovery);
            table.row(&[
                format!("{p}"),
                (*name).into(),
                format!("{sm:.3} ± {ss:.3}"),
                format!("{rm:.4}"),
                format!("{}", last.reshard_events),
            ]);
            rows.push(Json::obj(vec![
                ("p", Json::num(p as f64)),
                ("scenario", Json::str(name)),
                ("seconds_mean", Json::num(sm)),
                ("seconds_std", Json::num(ss)),
                ("recovery_seconds_mean", Json::num(rm)),
                ("faults", faults_json(&last)),
            ]));
        }
    }
    println!("{}", table.render());

    // checkpoint overhead + interrupt→resume cost on the native backend
    let dir = std::env::temp_dir().join(format!("dkkm_bench_ck_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut ck_cfg = cfg.clone();
    ck_cfg.checkpoint = Some(dir.clone());
    let t = Timer::start();
    let ck_run = MiniBatchKernelKMeans::new(ck_cfg.clone(), &NativeBackend).run(&source).unwrap();
    let ck_s = t.elapsed_s();
    assert_eq!(reference.labels, ck_run.labels, "checkpointing changed the run");

    let mut int_cfg = ck_cfg.clone();
    int_cfg.faults = Some(session(&format!("interrupt:{}", b / 2)));
    let interrupted = MiniBatchKernelKMeans::new(int_cfg, &NativeBackend).run(&source);
    assert!(interrupted.is_err(), "interrupt fault never fired");

    let mut res_cfg = ck_cfg.clone();
    res_cfg.resume = true;
    let resume_session = FaultSession::clean();
    res_cfg.faults = Some(resume_session.clone());
    let t = Timer::start();
    let resumed = MiniBatchKernelKMeans::new(res_cfg, &NativeBackend).run(&source).unwrap();
    let resume_s = t.elapsed_s();
    assert_eq!(reference.labels, resumed.labels, "resume diverged from the reference");
    let resume_rep = resume_session.report();
    println!(
        "checkpoint run {ck_s:.3}s vs clean {native_s:.3}s; resume from {:?}: {resume_s:.3}s",
        resume_rep.resumed_from_epoch
    );
    let _ = std::fs::remove_dir_all(&dir);

    let report = Json::obj(vec![
        ("bench", Json::str("faults")),
        ("n", Json::num(n as f64)),
        ("b", Json::num(b as f64)),
        ("c", Json::num(c as f64)),
        ("repeats", Json::num(repeats as f64)),
        ("scenarios", Json::arr(rows)),
        (
            "checkpoint",
            Json::obj(vec![
                ("clean_seconds", Json::num(native_s)),
                ("checkpointed_seconds", Json::num(ck_s)),
                ("resume_seconds", Json::num(resume_s)),
                (
                    "resumed_from_epoch",
                    resume_rep.resumed_from_epoch.map(|e| Json::num(e as f64)).unwrap_or(Json::Null),
                ),
            ]),
        ),
    ]);
    let out = std::env::var("DKKM_BENCH_OUT").unwrap_or_else(|_| "BENCH_faults.json".into());
    std::fs::write(&out, report.to_string()).expect("write bench json");
    println!("\nwrote {out}");
}
