# Pallas kernels for the kernel k-means inner-loop update (paper Eq.4-6 /
# Eq.15-17 in the landmark-sparsified form).
#
# Cluster state is carried as a landmark one-hot membership matrix
# M = onehot(U_L) of shape (L, C): column j selects the landmarks currently
# assigned to cluster j, so
#     f      = (K_NL . M) * inv_sizes          (cluster average similarity)
#     g_j    = inv_sizes_j^2 * M_j^T K_LL M_j  (cluster compactness)
#     labels = argmin_j  g_j - 2 f_ij          (Eq.4 / Eq.15)
#
# `valid` masks padded / empty cluster columns with +inf distance: AOT
# artifacts have a fixed C (Rust pads the one-hot with zero columns), and
# the paper's empty-cluster rule ("do not update, alpha = 0") also needs
# empty clusters excluded from the argmin.
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row tile for the assignment sweep. L and C stay whole inside the block:
# C <= 128 always, and the (L, C) one-hot tile is small (L <= 4096).
TILE_R = 128

# +inf stand-in that survives f32 round-trips (a plain Python float so it
# inlines as a literal instead of a captured constant inside pallas_call).
_BIG = 3.4e38


def _dot(a, b):
    return jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


def _assign_tile_kernel(k_ref, onehot_ref, inv_ref, g_ref, valid_ref, o_ref):
    k = k_ref[...]            # (TILE_R, L)
    onehot = onehot_ref[...]  # (L, C)
    inv = inv_ref[...]        # (1, C)
    g = g_ref[...]            # (1, C)
    valid = valid_ref[...]    # (1, C)
    f = _dot(k, onehot) * inv                      # (TILE_R, C)
    dist = jnp.where(valid > 0.0, g - 2.0 * f, _BIG)
    o_ref[...] = jnp.argmin(dist, axis=1).astype(jnp.int32)[:, None]


def assign_block(k, onehot, inv_sizes, g, valid):
    """Fused assignment step: labels = argmin_j g_j - 2 (K.M)_ij inv_j.

    k: (n, l) kernel rows vs landmarks; onehot: (l, c) landmark membership;
    inv_sizes, g, valid: (1, c). Returns (n, 1) i32 labels.
    """
    n, l = k.shape
    _, c = onehot.shape
    assert n % TILE_R == 0, n
    return pl.pallas_call(
        _assign_tile_kernel,
        grid=(n // TILE_R,),
        in_specs=[
            pl.BlockSpec((TILE_R, l), lambda i: (i, 0)),
            pl.BlockSpec((l, c), lambda i: (0, 0)),
            pl.BlockSpec((1, c), lambda i: (0, 0)),
            pl.BlockSpec((1, c), lambda i: (0, 0)),
            pl.BlockSpec((1, c), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_R, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.int32),
        interpret=True,
    )(k, onehot, inv_sizes, g, valid)


def _f_tile_kernel(k_ref, onehot_ref, o_ref):
    o_ref[...] = _dot(k_ref[...], onehot_ref[...])


def f_block(k, onehot):
    """Raw similarity partial sums (K.M) for one landmark chunk.

    The general path when L exceeds the fused artifact's landmark tile:
    Rust accumulates chunk results, then `argmin_block` finishes the update.
    k: (n, l); onehot: (l, c). Returns (n, c) f32 (un-normalized).
    """
    n, l = k.shape
    _, c = onehot.shape
    assert n % TILE_R == 0, n
    return pl.pallas_call(
        _f_tile_kernel,
        grid=(n // TILE_R,),
        in_specs=[
            pl.BlockSpec((TILE_R, l), lambda i: (i, 0)),
            pl.BlockSpec((l, c), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_R, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, c), jnp.float32),
        interpret=True,
    )(k, onehot)


def _compact_kernel(kll_ref, onehot_ref, inv_ref, o_ref):
    kll = kll_ref[...]        # (L, L)
    onehot = onehot_ref[...]  # (L, C)
    inv = inv_ref[...]        # (1, C)
    t = _dot(kll, onehot)     # (L, C)
    # diag(M^T K M)_j = sum_m M[m, j] * (K M)[m, j]
    quad = jnp.sum(onehot * t, axis=0, keepdims=True)  # (1, C)
    o_ref[...] = quad * inv * inv


def compactness(kll, onehot, inv_sizes):
    """Cluster compactness g_j = inv_j^2 . M_j^T K_LL M_j  (Eq.5 / Eq.16).

    kll: (l, l) landmark kernel block; onehot: (l, c); inv_sizes: (1, c).
    Returns (1, c) f32. Single block: the landmark set is VMEM-resident.
    """
    l, c = onehot.shape
    return pl.pallas_call(
        _compact_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((l, l), lambda i: (0, 0)),
            pl.BlockSpec((l, c), lambda i: (0, 0)),
            pl.BlockSpec((1, c), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, c), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, c), jnp.float32),
        interpret=True,
    )(kll, onehot, inv_sizes)


def _argmin_tile_kernel(f_ref, inv_ref, g_ref, valid_ref, o_ref):
    f = f_ref[...]            # (TILE_R, C) raw sums
    inv = inv_ref[...]        # (1, C)
    g = g_ref[...]            # (1, C)
    valid = valid_ref[...]    # (1, C)
    dist = jnp.where(valid > 0.0, g - 2.0 * f * inv, _BIG)
    o_ref[...] = jnp.argmin(dist, axis=1).astype(jnp.int32)[:, None]


def argmin_block(f_raw, inv_sizes, g, valid):
    """Finish the update from accumulated raw f sums (general-L path)."""
    n, c = f_raw.shape
    assert n % TILE_R == 0, n
    return pl.pallas_call(
        _argmin_tile_kernel,
        grid=(n // TILE_R,),
        in_specs=[
            pl.BlockSpec((TILE_R, c), lambda i: (i, 0)),
            pl.BlockSpec((1, c), lambda i: (0, 0)),
            pl.BlockSpec((1, c), lambda i: (0, 0)),
            pl.BlockSpec((1, c), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_R, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.int32),
        interpret=True,
    )(f_raw, inv_sizes, g, valid)
