//! Fig.6 — strong scaling: execution time vs node count P on the two
//! cluster substrates (IBM BG/Q 5D torus, IBM NeXtScale InfiniBand),
//! MNIST workload, B = 1.
//!
//! Per-shard compute throughput is *measured* on this host with a real
//! synthetic-MNIST Gram probe; collective costs come from the alpha-beta
//! topology model (DESIGN.md §3 substitution). Paper's shape: near-ideal
//! scaling 16 -> 1024 (BG/Q) / 16 -> 256 (NeXtScale), flattening beyond
//! as Amdahl's serial fraction + collective latency take over.
use dkkm::coordinator::{build_dataset, gamma_for, DatasetSpec};
use dkkm::distributed::{NetModel, ScalingSimulator, Topology};
use dkkm::kernels::{KernelFn, VecGram};
use dkkm::util::stats::{bench_scale, Table};

fn main() {
    let n = ((60_000.0 * bench_scale()) as usize).max(1000);
    println!("== Fig.6: strong scaling, MNIST-shaped workload N={n}, B=1, C=10 ==\n");

    // calibrate per-element costs on real data
    let probe_n = 1024.min(n);
    let (train, _) = build_dataset(&DatasetSpec::Mnist { train: probe_n, test: 0 }, 6);
    let gamma = gamma_for(&train, 4.0, 6);
    let probe = VecGram::new(train.x.clone(), KernelFn::Rbf { gamma }, 1);
    let cal = ScalingSimulator::calibrate(&probe, 512.min(probe_n), 512.min(probe_n), 7);
    println!(
        "calibration on this host: t_kernel={:.2e} s/elem, t_update={:.2e} s/elem\n",
        cal.t_kernel, cal.t_update
    );

    let ps = [16usize, 32, 64, 128, 256, 512, 1024, 2048];
    for (name, topo, paper_range) in [
        ("IBM BG/Q (5D torus)", Topology::BgqTorus5D, "16 -> 1024"),
        ("IBM NeXtScale (InfiniBand QDR)", Topology::InfinibandQdr, "16 -> 256"),
    ] {
        let sim = ScalingSimulator { net: NetModel::new(topo), n, l: n, c: 10, iters: 20 };
        let report = sim.sweep(cal, &ps);
        println!("--- {name} (paper: near-ideal {paper_range}) ---");
        let mut table =
            Table::new(&["P", "exec time (s)", "compute", "comm", "speedup", "efficiency"]);
        for pt in &report.points {
            table.row(&[
                pt.p.to_string(),
                format!("{:.3}", pt.total_s),
                format!("{:.3}", pt.compute_s),
                format!("{:.4}", pt.comm_s),
                format!("{:.1}", pt.speedup),
                format!("{:.2}", pt.efficiency),
            ]);
        }
        println!("{}", table.render());
    }
    println!("shape check: log-log-linear time decrease through the mid range,");
    println!("efficiency decaying at high P as comm latency + serial fraction");
    println!("dominate (Amdahl), BG/Q sustaining slightly further than IB (Fig.6).");
}
