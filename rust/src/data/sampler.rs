//! Mini-batch sampling strategies (paper §3.1, Fig.1b).
//!
//! * **Stride**: X^i = { x_{i + jB} } — decorrelates samples within a
//!   mini-batch; usable when the dataset is batch-available. The paper
//!   recommends it whenever possible.
//! * **Block**: X^i = { x_{i N/B + j} } — consecutive slices; the
//!   streaming-friendly choice, vulnerable to concept drift (Fig.4a).
use std::fmt;
use std::str::FromStr;

/// Mini-batch sampling strategy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Sampling {
    Stride,
    Block,
}

impl fmt::Display for Sampling {
    /// Canonical spec string; `display -> parse` round-trips.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Sampling::Stride => write!(f, "stride"),
            Sampling::Block => write!(f, "block"),
        }
    }
}

impl FromStr for Sampling {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "stride" => Ok(Sampling::Stride),
            "block" => Ok(Sampling::Block),
            other => Err(format!("unknown sampling '{other}' (stride|block)")),
        }
    }
}

/// Indices of mini-batch `i` out of `b`, over `n` samples.
///
/// Sizes differ by at most one when `b` does not divide `n`; every index
/// appears in exactly one mini-batch (tested below as a property).
pub fn minibatch_indices(n: usize, b: usize, i: usize, sampling: Sampling) -> Vec<usize> {
    assert!(b > 0 && i < b, "bad mini-batch index {i}/{b}");
    match sampling {
        Sampling::Stride => (0..).map(|j| i + j * b).take_while(|&x| x < n).collect(),
        Sampling::Block => {
            // contiguous ranges with the remainder spread over the first
            // (n % b) batches, matching the stride sizes
            let base = n / b;
            let rem = n % b;
            let lo = i * base + i.min(rem);
            let hi = lo + base + usize::from(i < rem);
            (lo..hi.min(n)).collect()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_partition(n: usize, b: usize, s: Sampling) {
        let mut seen = vec![0u8; n];
        for i in 0..b {
            for idx in minibatch_indices(n, b, i, s) {
                assert!(idx < n);
                seen[idx] += 1;
            }
        }
        assert!(
            seen.iter().all(|&c| c == 1),
            "partition violated for n={n} b={b} {s:?}"
        );
    }

    #[test]
    fn partition_property() {
        // every (n, b, strategy) combination must partition [0, n)
        for &n in &[1usize, 7, 100, 101, 1024, 999] {
            for &b in &[1usize, 2, 3, 8, 64] {
                if b <= n {
                    check_partition(n, b, Sampling::Stride);
                    check_partition(n, b, Sampling::Block);
                }
            }
        }
    }

    #[test]
    fn sizes_balanced() {
        for &(n, b) in &[(103usize, 4usize), (1000, 7), (64, 64)] {
            for s in [Sampling::Stride, Sampling::Block] {
                let sizes: Vec<usize> =
                    (0..b).map(|i| minibatch_indices(n, b, i, s).len()).collect();
                let min = sizes.iter().min().unwrap();
                let max = sizes.iter().max().unwrap();
                assert!(max - min <= 1, "unbalanced {sizes:?}");
            }
        }
    }

    #[test]
    fn stride_interleaves() {
        let idx = minibatch_indices(12, 3, 1, Sampling::Stride);
        assert_eq!(idx, vec![1, 4, 7, 10]);
    }

    #[test]
    fn block_is_contiguous() {
        let idx = minibatch_indices(12, 3, 1, Sampling::Block);
        assert_eq!(idx, vec![4, 5, 6, 7]);
        for w in idx.windows(2) {
            assert_eq!(w[1], w[0] + 1);
        }
    }

    #[test]
    fn parse_from_str() {
        assert_eq!("stride".parse::<Sampling>().unwrap(), Sampling::Stride);
        assert_eq!("block".parse::<Sampling>().unwrap(), Sampling::Block);
        assert!("other".parse::<Sampling>().is_err());
    }

    #[test]
    fn display_parse_round_trip() {
        for s in [Sampling::Stride, Sampling::Block] {
            assert_eq!(s.to_string().parse::<Sampling>().unwrap(), s);
        }
    }

    #[test]
    fn malformed_parse_names_alternatives() {
        let err = "zigzag".parse::<Sampling>().unwrap_err();
        assert!(err.contains("zigzag") && err.contains("stride|block"), "{err}");
    }

    #[test]
    fn single_batch_is_identity() {
        let idx = minibatch_indices(10, 1, 0, Sampling::Stride);
        assert_eq!(idx, (0..10).collect::<Vec<_>>());
    }
}
