//! Dependency-free symmetric eigendecomposition via cyclic Jacobi
//! rotations, the factorization behind the `nystrom:<rank>` engine:
//! W = K_ll = U Λ Uᵀ gives the rank-L feature map
//! Φ = K_nl · U · Λ^{-1/2} with Φ Φᵀ ≈ K (Chitta et al.).
//!
//! Landmark counts are small (L ≤ a few thousand), so the O(L³) Jacobi
//! sweep is cheap next to the O(N·L·d) `K_nl` fill it enables, and the
//! rotations are unconditionally stable on the symmetric PSD-ish inputs
//! kernel matrices produce. Accumulation runs in f64; results are
//! returned in the crate's f32 [`Mat`].
use crate::linalg::Mat;

/// Hard cap on full Jacobi sweeps; cyclic Jacobi converges
/// quadratically, so real inputs finish in well under 20.
const MAX_SWEEPS: usize = 64;

/// Eigendecomposition of a symmetric matrix.
#[derive(Clone, Debug)]
pub struct EigH {
    /// Eigenvalues, sorted descending.
    pub values: Vec<f32>,
    /// Orthonormal eigenvectors; column `j` pairs with `values[j]`.
    pub vectors: Mat,
}

/// Factor a symmetric `a` as `U Λ Uᵀ` with cyclic Jacobi rotations.
/// Only the lower/upper mean is read, so mildly asymmetric inputs
/// (accumulated f32 round-off in a Gram block) are symmetrized for free.
///
/// # Panics
/// On a non-square input.
pub fn jacobi_eigh(a: &Mat) -> EigH {
    let n = a.rows();
    assert_eq!(n, a.cols(), "jacobi_eigh needs a square matrix, got {}x{}", n, a.cols());
    if n == 0 {
        return EigH { values: Vec::new(), vectors: Mat::zeros(0, 0) };
    }
    // symmetrized f64 working copy + accumulated rotations
    let mut m = vec![0.0f64; n * n];
    for r in 0..n {
        for c in 0..n {
            m[r * n + c] = 0.5 * (a.at(r, c) as f64 + a.at(c, r) as f64);
        }
    }
    let mut v = vec![0.0f64; n * n];
    for d in 0..n {
        v[d * n + d] = 1.0;
    }

    let scale: f64 = m.iter().map(|x| x * x).sum::<f64>().max(f64::MIN_POSITIVE);
    for _ in 0..MAX_SWEEPS {
        let off: f64 = (0..n)
            .flat_map(|p| ((p + 1)..n).map(move |q| (p, q)))
            .map(|(p, q)| m[p * n + q] * m[p * n + q])
            .sum();
        // ~1e-11 relative per element — far below f32 output precision
        if off <= 1e-22 * scale {
            break;
        }
        for p in 0..n - 1 {
            for q in p + 1..n {
                let mpq = m[p * n + q];
                if mpq == 0.0 {
                    continue;
                }
                // rotation angle that zeroes m[p][q] (Golub & Van Loan):
                // t is the smaller root of t² + 2θt − 1 = 0
                let theta = (m[q * n + q] - m[p * n + p]) / (2.0 * mpq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // M <- Gᵀ M G, columns first then rows
                for k in 0..n {
                    let (mkp, mkq) = (m[k * n + p], m[k * n + q]);
                    m[k * n + p] = c * mkp - s * mkq;
                    m[k * n + q] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let (mpk, mqk) = (m[p * n + k], m[q * n + k]);
                    m[p * n + k] = c * mpk - s * mqk;
                    m[q * n + k] = s * mpk + c * mqk;
                }
                // V <- V G (eigenvectors accumulate as columns)
                for k in 0..n {
                    let (vkp, vkq) = (v[k * n + p], v[k * n + q]);
                    v[k * n + p] = c * vkp - s * vkq;
                    v[k * n + q] = s * vkp + c * vkq;
                }
            }
        }
    }

    // sort descending by eigenvalue, reordering the vector columns
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| {
        m[j * n + j].partial_cmp(&m[i * n + i]).unwrap_or(std::cmp::Ordering::Equal)
    });
    let values: Vec<f32> = order.iter().map(|&i| m[i * n + i] as f32).collect();
    let vectors = Mat::from_fn(n, n, |r, c| v[r * n + order[c]] as f32);
    EigH { values, vectors }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn mat_from(rows: usize, cols: usize, xs: &[f32]) -> Mat {
        Mat::from_vec(rows, cols, xs.to_vec()).unwrap()
    }

    #[test]
    fn diagonal_matrix_is_already_factored() {
        let a = mat_from(3, 3, &[3.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 2.0]);
        let e = jacobi_eigh(&a);
        assert_eq!(e.values, vec![3.0, 2.0, 1.0]);
        // columns are the (permuted, possibly sign-flipped) basis vectors
        for c in 0..3 {
            let col: Vec<f32> = (0..3).map(|r| e.vectors.at(r, c).abs()).collect();
            assert_eq!(col.iter().filter(|&&x| (x - 1.0).abs() < 1e-6).count(), 1);
        }
    }

    #[test]
    fn two_by_two_known_eigenvalues() {
        // [[2,1],[1,2]] has eigenvalues 3 and 1
        let a = mat_from(2, 2, &[2.0, 1.0, 1.0, 2.0]);
        let e = jacobi_eigh(&a);
        assert!((e.values[0] - 3.0).abs() < 1e-5, "{:?}", e.values);
        assert!((e.values[1] - 1.0).abs() < 1e-5, "{:?}", e.values);
    }

    #[test]
    fn reconstructs_random_symmetric_matrices() {
        let mut rng = Rng::new(7);
        for n in [1usize, 2, 5, 17, 40] {
            let mut a = Mat::zeros(n, n);
            for r in 0..n {
                for c in r..n {
                    let x = rng.normal32(0.0, 1.0);
                    a.set(r, c, x);
                    a.set(c, r, x);
                }
            }
            let e = jacobi_eigh(&a);
            // A ?= U Λ Uᵀ, elementwise
            for r in 0..n {
                for c in 0..n {
                    let mut acc = 0.0f64;
                    for k in 0..n {
                        acc += e.vectors.at(r, k) as f64
                            * e.values[k] as f64
                            * e.vectors.at(c, k) as f64;
                    }
                    assert!(
                        (acc as f32 - a.at(r, c)).abs() < 1e-3,
                        "n={n} ({r},{c}): {} vs {}",
                        acc,
                        a.at(r, c)
                    );
                }
            }
            // descending order
            for w in e.values.windows(2) {
                assert!(w[0] >= w[1] - 1e-6, "{:?}", e.values);
            }
        }
    }

    #[test]
    fn eigenvectors_are_orthonormal() {
        let mut rng = Rng::new(11);
        let n = 23;
        let mut a = Mat::zeros(n, n);
        for r in 0..n {
            for c in r..n {
                let x = rng.normal32(0.0, 1.0);
                a.set(r, c, x);
                a.set(c, r, x);
            }
        }
        let e = jacobi_eigh(&a);
        for i in 0..n {
            for j in 0..n {
                let dot: f64 = (0..n)
                    .map(|k| e.vectors.at(k, i) as f64 * e.vectors.at(k, j) as f64)
                    .sum();
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((dot - want).abs() < 1e-4, "({i},{j}): {dot}");
            }
        }
    }

    #[test]
    fn gram_like_psd_input_has_nonnegative_spectrum() {
        // X Xᵀ is PSD; the small negative round-off the solver may emit
        // must stay within f32 noise of zero
        let mut rng = Rng::new(3);
        let (n, d) = (12, 4);
        let x = Mat::from_fn(n, d, |_, _| rng.normal32(0.0, 1.0));
        let mut a = Mat::zeros(n, n);
        for r in 0..n {
            for c in 0..n {
                let dot: f32 = x.row(r).iter().zip(x.row(c)).map(|(p, q)| p * q).sum();
                a.set(r, c, dot);
            }
        }
        let e = jacobi_eigh(&a);
        for &w in &e.values {
            assert!(w > -1e-3, "PSD spectrum went negative: {:?}", e.values);
        }
    }

    #[test]
    fn empty_input() {
        let e = jacobi_eigh(&Mat::zeros(0, 0));
        assert!(e.values.is_empty());
        assert_eq!(e.vectors.rows(), 0);
    }
}
