//! The paper's distribution strategy (§3.3): row-wise sharding of the
//! mini-batch kernel matrix across P nodes, with two collectives per inner
//! iteration — allreduce(sum) of the C-vector `g` and allgather of the
//! label slices. Kernel matrix elements never cross the network.
//!
//! Three execution modes:
//! * [`ShardedBackend`] — real OS threads, one per node, exchanging data
//!   through the in-process [`comm`] collectives; numerically identical
//!   to the serial backend (tested), used to validate the distribution
//!   strategy end-to-end. The default, and the bit-identity oracle for
//!   the TCP mode below.
//! * [`TcpShardedBackend`] — real OS processes (`dkkm worker` children)
//!   exchanging the same collectives over a length-prefixed TCP
//!   protocol ([`transport`], `DKKM_TRANSPORT=tcp`), with wire-level
//!   fault tolerance: heartbeats, bounded reconnect, survivor re-shard.
//! * [`ScalingSimulator`] — per-shard compute is *measured*, network time
//!   is *modeled* ([`netmodel`], alpha-beta with per-topology parameters;
//!   the `measured` topology loads localhost parameters fitted from
//!   `BENCH_net.json`), so the Fig.6 strong-scaling curves extend to
//!   P = 1024 nodes on a single machine (DESIGN.md §3 substitutions).
pub mod comm;
pub mod fault;
pub mod netmodel;
pub mod shard;
pub mod sharded;
pub mod scaling;
pub mod transport;

pub use comm::{CollectiveError, Communicator, DEFAULT_DEADLINE};
pub use fault::{Fault, FaultPlan, FaultReport, FaultSession, WireFault};
pub use netmodel::{NetModel, Topology};
pub use shard::row_shards;
pub use sharded::ShardedBackend;
pub use scaling::{ScalingReport, ScalingSimulator};
pub use transport::{
    config_fingerprint, run_worker, TcpShardedBackend, TransportMode, TransportReport,
    WorkerOptions,
};
