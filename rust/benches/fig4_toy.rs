//! Fig.4 — 2D toy model: (a) centre evolution under block vs stride
//! sampling, (b) average medoid displacement per outer iteration as the
//! sampling-quality observable, (c) partial cost Omega(W^i) per inner
//! iteration, (d) global cost Omega(W) decreasing across the run.
//!
//! The paper's qualitative claims to reproduce:
//!   * stride sampling keeps displacement uniformly small; a
//!     concept-drifting block stream shows spikes,
//!   * each mini-batch's inner loop also drives the *global* cost down.
use dkkm::cluster::minibatch::NativeBackend;
use dkkm::cluster::{MiniBatchConfig, MiniBatchKernelKMeans};
use dkkm::coordinator::{build_dataset, gamma_for, DatasetSpec};
use dkkm::data::Sampling;
use dkkm::kernels::{KernelFn, VecGram};
use dkkm::metrics::accuracy;
use dkkm::util::stats::bench_scale;

fn main() {
    let per = ((2500.0 * bench_scale()) as usize).max(250);
    println!("== Fig.4: 2D toy, 4 Gaussian clusters x {per}, B=4 ==");
    println!("(paper: 10000 per cluster; DKKM_SCALE=4 for full size)\n");

    let (mut data, _) = build_dataset(&DatasetSpec::Toy2d { per_cluster: per }, 4);
    let gamma = gamma_for(&data, 0.15, 4);

    // make the stream concept-drift for block sampling (paper Fig.4a top:
    // "poorly designed block sampling"): sort samples by class
    let mut order: Vec<usize> = (0..data.n()).collect();
    order.sort_by_key(|&i| data.y[i]);
    data = data.subset(&order);
    let source = VecGram::new(data.x.clone(), KernelFn::Rbf { gamma }, 1);

    for sampling in [Sampling::Stride, Sampling::Block] {
        let mut mb = MiniBatchConfig::new(4, 4);
        mb.sampling = sampling;
        mb.seed = 21;
        mb.track_cost = true;
        let res = MiniBatchKernelKMeans::new(mb, &NativeBackend).run(&source).unwrap();
        println!("--- {sampling:?} sampling ---");
        println!("final accuracy: {:.2}%", accuracy(&res.labels, &data.y) * 100.0);
        println!("(b) medoid displacement per outer iteration:");
        for (i, rec) in res.history.iter().enumerate() {
            println!("    outer {i}: {:.4}", rec.medoid_displacement);
        }
        println!("(c) partial cost Omega(W^i) along inner iterations:");
        for (i, rec) in res.history.iter().enumerate() {
            let series: Vec<String> =
                rec.partial_cost.iter().map(|c| format!("{c:.1}")).collect();
            println!("    batch {i}: {}", series.join(" -> "));
        }
        println!("(d) sampled global cost Omega(W) after each merge:");
        let series: Vec<String> =
            res.history.iter().map(|r| format!("{:.1}", r.global_cost)).collect();
        println!("    {}\n", series.join(" -> "));
    }

    println!("shape check (Fig.4): stride displacement stays small & flat; the");
    println!("class-sorted block stream spikes; partial costs are monotone within");
    println!("each batch; global cost decreases across the outer loop.");
}
