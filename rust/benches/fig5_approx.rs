//! Fig.5 — the degree-of-approximation study: clustering accuracy (top
//! panel) and execution time (bottom panel) vs the landmark fraction s,
//! one curve per B in {1, 2, 4, 8}, on MNIST train/test.
//!
//! Paper's observations to reproduce:
//!   * robust across a wide (B, s) range,
//!   * accuracy decreases mildly as B grows,
//!   * for fixed B accuracy decays with s and *drops sharply below
//!     s ~ 0.2*,
//!   * execution time falls with both knobs.
use dkkm::prelude::*;
use dkkm::util::stats::{bench_repeats, bench_scale, mean_std, pm, Table};

fn main() {
    let scale = bench_scale();
    let train = ((3000.0 * scale) as usize).max(400);
    let test = train / 6;
    let repeats = bench_repeats();
    println!("== Fig.5: accuracy & time vs s, B in {{1,2,4,8}}, synthetic MNIST N={train} ==");
    println!("(paper: N=60000; DKKM_SCALE=20 for full size)\n");

    let s_values = [0.025f64, 0.05, 0.1, 0.2, 0.5, 1.0];
    let mut acc_table = Table::new(&["B \\ s", ".025", ".05", ".1", ".2", ".5", "1.0"]);
    let mut time_table = Table::new(&["B \\ s", ".025", ".05", ".1", ".2", ".5", "1.0"]);

    for &b in &[1usize, 2, 4, 8] {
        let mut acc_row = vec![format!("B={b}")];
        let mut time_row = vec![format!("B={b}")];
        for &s in &s_values {
            let (mut acc, mut tm) = (Vec::new(), Vec::new());
            for r in 0..repeats {
                let rep = Experiment::on(DatasetSpec::Mnist { train, test })
                    .clusters(10)
                    .batches(b)
                    .landmark_fraction(s)
                    .seed(400 + r as u64)
                    .build()
                    .expect("build")
                    .fit()
                    .expect("run");
                acc.push(rep.test_accuracy.unwrap() * 100.0);
                tm.push(rep.seconds.expect("timed run"));
            }
            let (am, astd) = mean_std(&acc);
            let (tmn, _) = mean_std(&tm);
            acc_row.push(pm(am, astd));
            time_row.push(format!("{tmn:.2}"));
        }
        acc_table.row(&acc_row);
        time_table.row(&time_row);
    }
    println!("(top panel) clustering accuracy %:");
    println!("{}", acc_table.render());
    println!("(bottom panel) execution time s:");
    println!("{}", time_table.render());
    println!("shape check: accuracy ~flat for s >= 0.2, degrading sharply below;");
    println!("larger B slightly lower; time decreasing in both B and s (Fig.5).");
}
