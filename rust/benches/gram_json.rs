//! bench-json harness: machine-readable Gram micro-kernel throughput.
//!
//! Fills the same `rows x cols` RBF Gram block through every SIMD tier
//! this host can execute (`linalg::simd`) plus the pre-micro-kernel
//! `dot4` baseline, across feature dimensions, and emits
//! `BENCH_gram.json` (override the path with `DKKM_BENCH_OUT`) with
//! GFLOP/s per dispatch tier and the speedup over the baseline — so the
//! compute-core speedup is a tracked number from PR to PR, not a claim.
//! Single-threaded on purpose: this measures the kernel, not the
//! thread pool (`pipeline_json` covers end-to-end runs).
//!
//!     cargo bench --bench gram_json
//!
//! Knobs: `DKKM_SCALE` multiplies the block shape, `DKKM_REPEATS` sets
//! timed repetitions per configuration (best-of is reported).
use dkkm::kernels::microkernel::{self, PackedPanel};
use dkkm::kernels::KernelFn;
use dkkm::linalg::{row_sq_norms, simd, Mat};
use dkkm::util::json::Json;
use dkkm::util::rng::Rng;
use dkkm::util::stats::{bench_repeats, bench_scale, Table, Timer};

/// Best-of-N wall time of `f` in seconds.
fn best_of(repeats: usize, mut f: impl FnMut()) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..repeats.max(1) {
        let t = Timer::start();
        f();
        best = best.min(t.elapsed_s());
    }
    best
}

fn main() {
    let scale = bench_scale();
    let rows = ((2048.0 * scale) as usize).max(128);
    let cols = ((512.0 * scale) as usize).clamp(64, rows);
    let repeats = bench_repeats();
    let tiers = simd::supported_tiers();
    let default_tier = simd::active_tier();
    println!(
        "== Gram micro-kernel bench: {rows}x{cols} RBF blocks, {repeats} repeats ==\n\
         host tiers: {:?}, dispatching: {default_tier}\n",
        tiers.iter().map(|t| t.name()).collect::<Vec<_>>()
    );

    let mut table = Table::new(&["d", "path", "seconds", "GFLOP/s", "vs dot4"]);
    let mut results = Vec::new();
    for &d in &[16usize, 64, 256] {
        // gamma ~ 1/d keeps RBF outputs near e^-1 for N(0,1) data
        // (E[d2] ≈ 2d), so the cross-tier equivalence assertion compares
        // meaningful values at every depth instead of saturating to ~0
        let kernel = KernelFn::Rbf { gamma: 1.0 / (2.0 * d as f32) };
        let mut rng = Rng::new(0xB5E + d as u64);
        let x = Mat::from_fn(rows, d, |_, _| rng.normal32(0.0, 1.0));
        let row_idx: Vec<usize> = (0..rows).collect();
        let col_idx: Vec<usize> = (0..cols).map(|j| (j * rows / cols) % rows).collect();
        let xn = row_sq_norms(&x);
        let yn: Vec<f32> = col_idx.iter().map(|&j| xn[j]).collect();
        let flops = 2.0 * rows as f64 * cols as f64 * d as f64;

        // --- baseline: the pre-PR-4 autovectorized dot4 path
        let mut base_out = vec![0.0f32; rows * cols];
        let base_s = best_of(repeats, || {
            microkernel::fill_block_dot4(&x, &row_idx, &col_idx, kernel, &mut base_out);
        });
        let base_gflops = flops / base_s / 1e9;
        table.row(&[
            format!("{d}"),
            "dot4-reference".into(),
            format!("{base_s:.4}"),
            format!("{base_gflops:.2}"),
            "1.00x".into(),
        ]);
        results.push(Json::obj(vec![
            ("d", Json::num(d as f64)),
            ("path", Json::str("dot4-reference")),
            ("seconds_best", Json::num(base_s)),
            ("gflops", Json::num(base_gflops)),
            ("speedup_vs_dot4", Json::num(1.0)),
        ]));

        // --- every executable tier of the dispatched micro-kernel
        // (packing is timed too: it is part of every block fill)
        for &tier in &tiers {
            let mut out = vec![0.0f32; rows * cols];
            let s = best_of(repeats, || {
                let packed = PackedPanel::pack_gather(&x, &col_idx);
                microkernel::fill_gram_rows(
                    tier, &x, &row_idx, &packed, &xn, &yn, kernel, &mut out,
                );
            });
            // equivalence spot-check against the baseline
            let max_diff = out
                .iter()
                .zip(&base_out)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(
                max_diff < 1e-3,
                "tier {tier} diverges from dot4 at d={d}: max |diff| = {max_diff}"
            );
            let gflops = flops / s / 1e9;
            let speedup = base_s / s;
            table.row(&[
                format!("{d}"),
                tier.name().into(),
                format!("{s:.4}"),
                format!("{gflops:.2}"),
                format!("{speedup:.2}x"),
            ]);
            results.push(Json::obj(vec![
                ("d", Json::num(d as f64)),
                ("path", Json::str(tier.name())),
                ("seconds_best", Json::num(s)),
                ("gflops", Json::num(gflops)),
                ("speedup_vs_dot4", Json::num(speedup)),
            ]));
        }
    }
    println!("{}", table.render());

    let report = Json::obj(vec![
        ("bench", Json::str("gram")),
        ("rows", Json::num(rows as f64)),
        ("cols", Json::num(cols as f64)),
        ("repeats", Json::num(repeats as f64)),
        ("dispatch_tier", Json::str(default_tier.name())),
        (
            "host_tiers",
            Json::arr(tiers.iter().map(|t| Json::str(t.name()))),
        ),
        ("results", Json::arr(results)),
    ]);
    let out = std::env::var("DKKM_BENCH_OUT").unwrap_or_else(|_| "BENCH_gram.json".into());
    std::fs::write(&out, report.to_string()).expect("write bench json");
    println!("\nwrote {out}");
}
