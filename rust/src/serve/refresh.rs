//! Background refresh: continue mini-batch iteration on appended data
//! and publish a new model per epoch through the hot-swap slot.
//!
//! One refresh epoch is one outer-loop step of Alg.1 over the appended
//! block, seeded from the *serving* medoids instead of k-means++: Eq.8
//! assignment of the appended rows to the current medoids, the inner GD
//! loop (Eq.15-17) to a label fixed point with the appended rows as
//! landmarks, Eq.7/10 medoid extraction, and the Eq.11-13 convex merge
//! against the carried cluster weights — the exact
//! [`crate::cluster::merge_medoid`] rule the fit used, so a refreshed
//! model is what the fit would have produced had the data arrived one
//! batch later. The epoch is RNG-free and therefore deterministic:
//! generation-pinned equivalence tests can replay it.
//!
//! The working set is small (C medoid rows + the appended block), so
//! each epoch builds a throwaway [`VecGram`] over it; the serving path
//! never waits — [`Refresher`] computes off-lock and swaps via
//! [`ModelSlot::publish`].
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::cluster::assign::{inner_iteration, similarity_f};
use crate::cluster::{assign_to_medoids, merge_medoid};
use crate::data::CsrMat;
use crate::kernels::{GramSource, VecGram};
use crate::linalg::Mat;
use crate::util::error::{Error, Result};

use super::model::{RowBlock, ServeModel};
use super::swap::ModelSlot;

/// Knobs for one refresh epoch.
#[derive(Clone, Copy, Debug)]
pub struct RefreshConfig {
    /// Inner GD iteration cap (the fit's default is 100; refresh blocks
    /// are small, 30 converges in practice).
    pub max_inner: usize,
}

impl Default for RefreshConfig {
    fn default() -> RefreshConfig {
        RefreshConfig { max_inner: 30 }
    }
}

/// Concatenate the model's medoid rows with the appended block into one
/// working set (rows `0..c` = medoids, `c..c+nb` = appended).
fn combined_gram(model: &ServeModel, appended: &RowBlock) -> Result<VecGram> {
    if appended.rows() == 0 {
        return Err(Error::Config("refresh needs at least one appended row".into()));
    }
    if appended.dim() != model.dim() {
        return Err(Error::Shape(format!(
            "appended rows have dimension {}, the model serves {}",
            appended.dim(),
            model.dim()
        )));
    }
    match (model.features(), appended) {
        (RowBlock::Dense(med), RowBlock::Dense(rows)) => {
            let mut data = Vec::with_capacity((med.rows() + rows.rows()) * med.cols());
            data.extend_from_slice(med.data());
            data.extend_from_slice(rows.data());
            let combined = Mat::from_vec(med.rows() + rows.rows(), med.cols(), data)?;
            Ok(VecGram::new(combined, model.kernel(), 1))
        }
        (RowBlock::Csr(med), RowBlock::Csr(rows)) => {
            let mut entry_rows = Vec::with_capacity(med.rows() + rows.rows());
            for r in 0..med.rows() {
                let (idx, vals) = med.row(r);
                entry_rows.push(
                    idx.iter().map(|&i| i as usize).zip(vals.iter().copied()).collect(),
                );
            }
            for r in 0..rows.rows() {
                let (idx, vals) = rows.row(r);
                entry_rows.push(
                    idx.iter().map(|&i| i as usize).zip(vals.iter().copied()).collect(),
                );
            }
            let combined = CsrMat::from_rows(med.cols(), entry_rows);
            Ok(VecGram::from_csr(combined, model.kernel(), 1))
        }
        _ => Err(Error::Config(format!(
            "appended rows are {} but the model stores {} features",
            appended.storage(),
            model.features().storage()
        ))),
    }
}

/// Run one refresh epoch (see module docs) and return the new model.
/// Deterministic in (model, appended) — no RNG is consulted.
pub fn refresh_epoch(
    model: &ServeModel,
    appended: &RowBlock,
    cfg: &RefreshConfig,
) -> Result<ServeModel> {
    let c = model.c();
    let gram = combined_gram(model, appended)?;
    let nb = appended.rows();
    let meds: Vec<usize> = (0..c).collect();
    let batch: Vec<usize> = (c..c + nb).collect();

    // Eq.8 seeding from the serving medoids
    let mut batch_labels = assign_to_medoids(&gram, &batch, &meds);
    let mut diag = vec![0.0f32; nb];
    gram.diag(&batch, &mut diag);

    // inner GD loop to a label fixed point; the appended rows are both
    // the block and the landmark set, so K_bl = K_ll
    let k_ll = gram.block_mat(&batch, &batch);
    let mut stats;
    let mut iters = 0;
    loop {
        let (new_labels, new_stats) = inner_iteration(&k_ll, &k_ll, &batch_labels, c);
        stats = new_stats;
        let fixed = new_labels == batch_labels;
        batch_labels = new_labels;
        iters += 1;
        if fixed || iters >= cfg.max_inner.max(1) {
            break;
        }
    }

    // Eq.7/10 medoid extraction over the appended block
    let f = similarity_f(&k_ll, &batch_labels, &stats);
    let batch_medoids: Vec<Option<usize>> = (0..c)
        .map(|j| {
            if stats.counts[j] == 0 {
                return None;
            }
            let mut best = None;
            let mut best_v = f32::INFINITY;
            for r in 0..nb {
                let v = diag[r] - 2.0 * f.at(r, j);
                if v < best_v {
                    best_v = v;
                    best = Some(batch[r]);
                }
            }
            best
        })
        .collect();
    let mut batch_counts = vec![0usize; c];
    for &u in &batch_labels {
        batch_counts[u] += 1;
    }

    // Eq.11-13 convex merge against the carried weights
    let mut weights = model.weights().to_vec();
    let mut new_meds: Vec<usize> = meds.clone();
    for j in 0..c {
        let Some(m_new) = batch_medoids[j] else {
            continue; // cluster empty in this block: alpha = 0
        };
        if weights[j] == 0 {
            new_meds[j] = m_new; // first real content: alpha = 1
        } else {
            let alpha = batch_counts[j] as f64 / (batch_counts[j] + weights[j]) as f64;
            new_meds[j] = merge_medoid(&gram, &batch, &diag, j, m_new, alpha);
        }
        weights[j] += batch_counts[j];
    }

    // re-materialize medoid features from the working set
    let features = match gram.storage() {
        crate::kernels::VecStorage::Dense(x) => RowBlock::Dense(x.gather(&new_meds)),
        crate::kernels::VecStorage::Csr(x) => RowBlock::Csr(x.gather(&new_meds)),
    };
    ServeModel::from_features(
        features,
        model.kernel(),
        weights,
        new_meds,
        model.fingerprint().clone(),
    )
}

/// Background refresh thread: appended blocks in, one published
/// generation out per block. Dropping the handle (or calling
/// [`Refresher::finish`]) closes the channel and joins the thread.
pub struct Refresher {
    tx: Option<Sender<RowBlock>>,
    handle: Option<JoinHandle<Result<u64>>>,
}

impl Refresher {
    pub fn spawn(slot: Arc<ModelSlot>, cfg: RefreshConfig) -> Refresher {
        let (tx, rx) = channel::<RowBlock>();
        let handle = std::thread::spawn(move || -> Result<u64> {
            let mut epochs = 0u64;
            while let Ok(block) = rx.recv() {
                let pinned = slot.load();
                let next = refresh_epoch(&pinned.model, &block, &cfg)?;
                slot.publish(next);
                epochs += 1;
            }
            Ok(epochs)
        });
        Refresher { tx: Some(tx), handle: Some(handle) }
    }

    /// Queue an appended block for the next epoch (non-blocking).
    pub fn append(&self, rows: RowBlock) -> Result<()> {
        self.tx
            .as_ref()
            .expect("refresher channel alive until finish()")
            .send(rows)
            .map_err(|_| Error::Runtime("refresh thread exited early".into()))
    }

    /// Close the queue, drain remaining blocks, join; returns how many
    /// epochs were published.
    pub fn finish(mut self) -> Result<u64> {
        drop(self.tx.take());
        let handle = self.handle.take().expect("finish() runs once");
        handle
            .join()
            .map_err(|_| Error::Runtime("refresh thread panicked".into()))?
    }
}

impl Drop for Refresher {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::KernelFn;
    use crate::serve::model::SnapshotFingerprint;
    use crate::util::rng::Rng;

    fn clustered_data(seed: u64, per: usize) -> Mat {
        let mut rng = Rng::new(seed);
        let centers = [(-4.0f32, -4.0f32), (4.0, 4.0), (4.0, -4.0)];
        Mat::from_fn(per * 3, 2, |r, c| {
            let (cx, cy) = centers[r / per];
            let base = if c == 0 { cx } else { cy };
            base + rng.normal32(0.0, 0.5)
        })
    }

    fn seed_model(x: &Mat) -> ServeModel {
        let medoids = vec![0usize, 1, 2]; // all from cluster 0: refresh must fix this
        ServeModel::from_features(
            RowBlock::Dense(x.gather(&medoids)),
            KernelFn::Rbf { gamma: 0.2 },
            vec![1; 3],
            medoids,
            SnapshotFingerprint::adhoc("dense", 3, x.rows()),
        )
        .unwrap()
    }

    #[test]
    fn refresh_epoch_is_deterministic() {
        let x = clustered_data(9, 12);
        let model = seed_model(&x);
        let appended = RowBlock::Dense(x.clone());
        let cfg = RefreshConfig::default();
        let a = refresh_epoch(&model, &appended, &cfg).unwrap();
        let b = refresh_epoch(&model, &appended, &cfg).unwrap();
        assert_eq!(a.medoids(), b.medoids());
        assert_eq!(a.weights(), b.weights());
        let labels_a = a.assign_dense(&x).unwrap();
        let labels_b = b.assign_dense(&x).unwrap();
        assert_eq!(labels_a, labels_b);
    }

    #[test]
    fn refresh_accumulates_weights() {
        let x = clustered_data(5, 10);
        let model = seed_model(&x);
        let appended = RowBlock::Dense(x.clone());
        let next = refresh_epoch(&model, &appended, &RefreshConfig::default()).unwrap();
        let total: usize = next.weights().iter().sum();
        assert_eq!(total, 3 + x.rows(), "every appended row joins a cluster once");
    }

    #[test]
    fn storage_mismatch_is_structured() {
        let x = clustered_data(5, 6);
        let model = seed_model(&x);
        let appended = RowBlock::Csr(CsrMat::from_dense(&x));
        let err = refresh_epoch(&model, &appended, &RefreshConfig::default()).unwrap_err();
        assert!(format!("{err}").contains("dense"), "{err}");
    }

    #[test]
    fn refresher_publishes_per_block() {
        let x = clustered_data(3, 8);
        let slot = Arc::new(ModelSlot::new(seed_model(&x)));
        let refresher = Refresher::spawn(slot.clone(), RefreshConfig::default());
        refresher.append(RowBlock::Dense(x.clone())).unwrap();
        refresher.append(RowBlock::Dense(x.clone())).unwrap();
        let epochs = refresher.finish().unwrap();
        assert_eq!(epochs, 2);
        assert_eq!(slot.generation(), 2);
    }
}
