//! Tab.1 — MNIST: clustering accuracy, NMI and execution time for
//! B in {1, 4, 16, 64}, plus the linear k-means baseline row.
//!
//! Paper (60000 train / 10000 test, C = 10, sigma = 4 d_max, stride,
//! s = 1):
//!   Baseline  84.5 ± 0.62    0.693 ± 0.012       —
//!   B=1       86.47 ± 0.37   0.737 ± 0.006   655.23 ± 82.92 s
//!   B=4       82.63 ± 0.91   0.680 ± 0.011   133.63 ± 4.40 s
//!   B=16      81.45 ± 0.65   0.670 ± 0.010    32.17 ± 2.48 s
//!   B=64      78.39 ± 0.95   0.626 ± 0.015     9.51 ± 0.58 s
//!
//! Expected *shape* on the synthetic substitute: accuracy/NMI highest at
//! B=1 and decreasing gently with B; time dropping ~linearly in 1/B.
//! Default N is scaled for this single-core host (DKKM_SCALE=12.5 for
//! paper-size 60k).
use dkkm::coordinator::run_lloyd_baseline;
use dkkm::prelude::*;
use dkkm::util::stats::{bench_repeats, bench_scale, mean_std, pm, Table};

fn main() {
    let scale = bench_scale();
    let train = ((4800.0 * scale) as usize).max(500);
    let test = train / 6;
    let repeats = bench_repeats();
    println!("== Tab.1: synthetic MNIST, N={train} train / {test} test, C=10, s=1, stride ==");
    println!("(paper: N=60000; run with DKKM_SCALE=12.5 to reproduce at full size)\n");

    let mut table = Table::new(&["B", "Clustering accuracy", "NMI", "Execution time (s)"]);

    // baseline: linear k-means (scikit-learn stand-in)
    let (mut acc, mut nm) = (Vec::new(), Vec::new());
    for r in 0..repeats {
        let spec = DatasetSpec::Mnist { train, test };
        let (_, _, a, n) = run_lloyd_baseline(&spec, 10, 100 + r as u64).expect("baseline");
        acc.push(a.unwrap() * 100.0);
        nm.push(n.unwrap());
    }
    let (am, astd) = mean_std(&acc);
    let (nmn, nstd) = mean_std(&nm);
    table.row(&[
        "Baseline".into(),
        pm(am, astd),
        pm(nmn, nstd),
        "—".into(),
    ]);

    for &b in &[1usize, 4, 16, 64] {
        let (mut acc, mut nm, mut tm) = (Vec::new(), Vec::new(), Vec::new());
        for r in 0..repeats {
            let rep = Experiment::on(DatasetSpec::Mnist { train, test })
                .clusters(10)
                .batches(b)
                .seed(100 + r as u64)
                .build()
                .expect("build")
                .fit()
                .expect("run");
            acc.push(rep.test_accuracy.unwrap() * 100.0);
            nm.push(rep.test_nmi.unwrap());
            tm.push(rep.seconds.expect("timed run"));
        }
        let (am, astd) = mean_std(&acc);
        let (nmn, nstd) = mean_std(&nm);
        let (tmn, tstd) = mean_std(&tm);
        table.row(&[b.to_string(), pm(am, astd), pm(nmn, nstd), pm(tmn, tstd)]);
    }
    println!("{}", table.render());
    println!("shape check: accuracy decreases with B, time ~ 1/B (paper Tab.1).");
}
