//! §Perf hot-path microbenchmarks (EXPERIMENTS.md §Perf feeds from this):
//!
//!   1. Gram-block evaluation throughput: native blocked CPU path vs the
//!      PJRT artifact (Pallas rbf tile) per feature dimension,
//!   2. inner-iteration latency: native vs PJRT fused executable vs the
//!      row-sharded backend at several node counts,
//!   3. collective costs of the in-process communicator,
//!   4. offload pipeline overlap on a realistic mini-batch run.
use dkkm::cluster::assign;
use dkkm::cluster::minibatch::{MiniBatchConfig, MiniBatchKernelKMeans, NativeBackend, StepBackend};
use dkkm::coordinator::{build_dataset, gamma_for, shared_pjrt, DatasetSpec};
use dkkm::distributed::comm::Communicator;
use dkkm::distributed::ShardedBackend;
use dkkm::kernels::{GramSource, KernelFn, VecGram};
use dkkm::runtime::{PjrtBackend, PjrtGram};
use dkkm::util::rng::Rng;
use dkkm::util::stats::{Table, Timer};

fn bench<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let t = Timer::start();
    for _ in 0..reps {
        f();
    }
    t.elapsed_s() / reps as f64
}

fn main() {
    println!("== §Perf hot-path microbenchmarks ==\n");

    // ---------------- 1. Gram tile throughput
    println!("1) Gram block evaluation, 512x512 block (M kernel-elems/s):");
    let mut table = Table::new(&["d", "native (1 thread)", "pjrt (artifact)"]);
    for &d in &[64usize, 256, 784] {
        let (data, _) = build_dataset(&DatasetSpec::Mnist { train: 512, test: 0 }, 1);
        // re-project to d dims by truncation for the bench
        let x = dkkm::linalg::Mat::from_fn(512, d, |r, c| data.x.at(r, c % 784));
        let gamma = 0.01f32;
        let rows: Vec<usize> = (0..512).collect();
        let native = VecGram::new(x.clone(), KernelFn::Rbf { gamma }, 1);
        let t_native = bench(1, 3, || {
            let _ = native.block_mat(&rows, &rows);
        });
        let pjrt_cell = match shared_pjrt().and_then(|rt| PjrtGram::new(rt, x.clone(), gamma)) {
            Ok(pj) => {
                let t_pjrt = bench(1, 3, || {
                    let _ = pj.block_mat(&rows, &rows);
                });
                format!("{:.1}", 512.0 * 512.0 / t_pjrt / 1e6)
            }
            Err(_) => "n/a".into(),
        };
        table.row(&[
            d.to_string(),
            format!("{:.1}", 512.0 * 512.0 / t_native / 1e6),
            pjrt_cell,
        ]);
    }
    println!("{}", table.render());

    // ---------------- 2. inner iteration latency
    println!("2) inner-loop iteration latency (ms), N=2048 rows, L=256, C=10:");
    let mut rng = Rng::new(0);
    let x = dkkm::linalg::Mat::from_fn(2048, 32, |_, _| rng.normal32(0.0, 1.0));
    let g = VecGram::new(x, KernelFn::Rbf { gamma: 0.1 }, 1);
    let rows: Vec<usize> = (0..2048).collect();
    let lms: Vec<usize> = (0..256).collect();
    let k_nl = g.block_mat(&rows, &lms);
    let k_ll = g.block_mat(&lms, &lms);
    let labels: Vec<usize> = (0..256).map(|_| rng.below(10)).collect();
    let mut table = Table::new(&["backend", "ms/iteration"]);
    let t = bench(2, 10, || {
        let _ = assign::inner_iteration(&k_nl, &k_ll, &labels, 10);
    });
    table.row(&["native".into(), format!("{:.2}", t * 1e3)]);
    if let Ok(rt) = shared_pjrt() {
        let backend = PjrtBackend::new(rt);
        let t = bench(2, 10, || {
            let _ = backend.iterate_mat(&k_nl, &k_ll, &labels, 10);
        });
        table.row(&["pjrt (fused artifact)".into(), format!("{:.2}", t * 1e3)]);
    }
    for p in [2usize, 4, 8] {
        let backend = ShardedBackend::new(p);
        let t = bench(2, 10, || {
            let _ = backend.iterate_mat(&k_nl, &k_ll, &labels, 10);
        });
        table.row(&[format!("sharded p={p}"), format!("{:.2}", t * 1e3)]);
    }
    println!("{}", table.render());

    // ---------------- 3. collectives
    println!("3) in-process collectives (us/op, 8 nodes):");
    let mut table = Table::new(&["op", "us"]);
    for (name, msg) in [("allreduce g (C=32 f32)", 32usize), ("allreduce g (C=1024)", 1024)] {
        let t = {
            let comm = Communicator::new(8);
            let reps = 200;
            let t = Timer::start();
            std::thread::scope(|scope| {
                for rank in 0..8 {
                    let mut node = comm.node(rank);
                    scope.spawn(move || {
                        let local = vec![1.0f32; msg];
                        for _ in 0..reps {
                            let _ = node.allreduce_sum(&local);
                        }
                    });
                }
            });
            t.elapsed_s() / reps as f64
        };
        table.row(&[name.into(), format!("{:.1}", t * 1e6)]);
    }
    println!("{}", table.render());

    // ---------------- 4. offload overlap
    println!("4) offload pipeline overlap (synthetic MNIST N=2000, B=8):");
    let (data, _) = build_dataset(&DatasetSpec::Mnist { train: 2000, test: 0 }, 9);
    let gamma = gamma_for(&data, 4.0, 9);
    let source = VecGram::new(data.x.clone(), KernelFn::Rbf { gamma }, 1);
    for offload in [false, true] {
        let mut mb = MiniBatchConfig::new(10, 8);
        mb.seed = 13;
        mb.offload = offload;
        let t = Timer::start();
        let res = MiniBatchKernelKMeans::new(mb, &NativeBackend).run(&source).unwrap();
        let total = t.elapsed_s();
        match res.overlap {
            Some(ov) => println!(
                "   offload=on : {total:.2}s total, producer busy {:.2}s, \
                 consumer waited {:.2}s (overlap {:.0}%)",
                ov.producer_busy_s,
                ov.consumer_wait_s,
                ov.overlap_efficiency() * 100.0
            ),
            None => println!("   offload=off: {total:.2}s total"),
        }
    }
}
