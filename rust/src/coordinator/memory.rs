//! Memory planner (paper Eq.19).
//!
//! Per-node footprint of one mini-batch iteration (Q bytes per scalar):
//!
//!   M(B) = Q * [ N/(BP) * (N/B + C)   -- K rows + K~ rows
//!              + N/B                  -- labels U
//!              + 2C ]                 -- g + medoid scratch
//!
//! `b_min` solves M(B) <= R exactly (quadratic in x = N/B, then ceil),
//! which is the calculation Eq.19 expresses in closed form. The paper's
//! printed formula has a small typo in the discriminant (its `-8C/P`
//! cross-term does not follow from the stated footprint); we implement
//! the exact solution and also expose [`paper_b_min`] verbatim for
//! comparison — the two agree wherever the paper's discriminant is valid.

/// Bytes per scalar (f32).
pub const Q: usize = 4;

/// Per-node memory footprint in bytes for the given mini-batch count.
pub fn footprint_bytes(n: usize, b: usize, p: usize, c: usize) -> usize {
    assert!(b > 0 && p > 0);
    let nb = n.div_ceil(b); // N/B
    let rows = nb.div_ceil(p); // N/(BP)
    Q * (rows * (nb + c) + nb + 2 * c)
}

/// Smallest B whose footprint fits in `r_bytes` per node (exact solve of
/// the Eq.19 quadratic, then verified by direct evaluation).
pub fn b_min(n: usize, p: usize, c: usize, r_bytes: usize) -> Option<usize> {
    // x = N/B; Q [ x^2/P + x C/P + x + 2C ] <= R
    // => x^2/P + x (C/P + 1) + (2C - R/Q) <= 0
    let pf = p as f64;
    let cf = c as f64;
    let r_q = r_bytes as f64 / Q as f64;
    let a = 1.0 / pf;
    let bq = cf / pf + 1.0;
    let cq = 2.0 * cf - r_q;
    let disc = bq * bq - 4.0 * a * cq;
    if disc < 0.0 {
        return None; // even B = N (single-sample batches) cannot fit
    }
    let x_max = (-bq + disc.sqrt()) / (2.0 * a);
    if x_max < 1.0 {
        return None;
    }
    let mut b = ((n as f64) / x_max).ceil().max(1.0) as usize;
    // guard against float edge cases: walk to the exact boundary
    while footprint_bytes(n, b, p, c) > r_bytes {
        b += 1;
        if b > n {
            return None;
        }
    }
    while b > 1 && footprint_bytes(n, b - 1, p, c) <= r_bytes {
        b -= 1;
    }
    Some(b)
}

/// The paper's Eq.19 exactly as printed (for the comparison test/report):
/// B_min = (2N/P) / ( -(C/P + 1) + sqrt((C/P + 1)^2 - 8C/P + R/Q) ).
pub fn paper_b_min(n: usize, p: usize, c: usize, r_bytes: usize) -> Option<f64> {
    let pf = p as f64;
    let cf = c as f64;
    let r_q = r_bytes as f64 / Q as f64;
    let bq = cf / pf + 1.0;
    let disc = bq * bq - 8.0 * cf / pf + r_q;
    if disc < 0.0 {
        return None;
    }
    let denom = -bq + disc.sqrt();
    if denom <= 0.0 {
        return None;
    }
    Some(2.0 * (n as f64) / pf / denom)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn footprint_decreases_with_b() {
        let f1 = footprint_bytes(60_000, 1, 16, 10);
        let f4 = footprint_bytes(60_000, 4, 16, 10);
        let f64_ = footprint_bytes(60_000, 64, 16, 10);
        assert!(f1 > f4 && f4 > f64_);
    }

    #[test]
    fn footprint_decreases_with_p() {
        assert!(
            footprint_bytes(60_000, 4, 1, 10) > footprint_bytes(60_000, 4, 64, 10)
        );
    }

    #[test]
    fn b_min_fits_and_is_minimal_property() {
        for &(n, p, c, r) in &[
            (60_000usize, 16usize, 10usize, 1usize << 30),
            (60_000, 1, 10, 1 << 30),
            (1_000_000, 64, 20, 8 << 30),
            (10_000, 4, 4, 64 << 20),
            (188_000, 16, 50, 2 << 30),
        ] {
            let b = b_min(n, p, c, r).unwrap_or_else(|| panic!("no b for {n} {p} {c}"));
            assert!(
                footprint_bytes(n, b, p, c) <= r,
                "footprint(B_min) exceeds R for n={n} p={p}"
            );
            if b > 1 {
                assert!(
                    footprint_bytes(n, b - 1, p, c) > r,
                    "B_min not minimal for n={n} p={p} (b={b})"
                );
            }
        }
    }

    #[test]
    fn small_memory_forces_more_batches() {
        let b_big = b_min(60_000, 16, 10, 8 << 30).unwrap();
        let b_small = b_min(60_000, 16, 10, 64 << 20).unwrap();
        assert!(b_small > b_big, "{b_small} vs {b_big}");
    }

    #[test]
    fn impossible_budget_returns_none() {
        // even single-sample mini-batches need ~Q*2C bytes
        assert_eq!(b_min(1000, 1, 100, 64), None);
    }

    #[test]
    fn mnist_single_batch_fits_in_16g_per_core() {
        // paper §4.3: MNIST B=1 on BG/Q (16 GB/core, 16 cores/node);
        // with P = 16 the 60000^2 kernel slab is ~900 MB/node
        let b = b_min(60_000, 16, 10, 16 << 30).unwrap();
        assert_eq!(b, 1);
    }

    #[test]
    fn paper_formula_close_to_exact_in_its_regime() {
        // where R/Q dominates the discriminant the printed formula and
        // the exact solve agree to within rounding
        let n = 60_000;
        let (p, c, r) = (16usize, 10usize, 256usize << 20);
        let exact = b_min(n, p, c, r).unwrap() as f64;
        let printed = paper_b_min(n, p, c, r).unwrap();
        let ratio = exact / printed.max(1.0);
        assert!((0.4..2.5).contains(&ratio), "exact {exact} vs printed {printed}");
    }
}
