//! Crate-wide error type.
use thiserror::Error;

/// Errors surfaced by the dkkm library.
#[derive(Error, Debug)]
pub enum Error {
    /// Invalid configuration or CLI arguments.
    #[error("config error: {0}")]
    Config(String),
    /// Shape/dimension mismatch in a linear-algebra or clustering op.
    #[error("shape error: {0}")]
    Shape(String),
    /// PJRT runtime failure.
    #[error("runtime error: {0}")]
    Runtime(String),
    /// I/O failure.
    #[error(transparent)]
    Io(#[from] std::io::Error),
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
