//! Tab.3 — noisy MNIST (paper: 10^6 samples): accuracy, NMI, time for
//! B in {32, 64}. The full-batch baseline row is "—" in the paper too:
//! the N^2 kernel matrix is simply infeasible, which is the point of the
//! mini-batch scheme.
//!
//! Paper:
//!   B=32   64.19 ± 1.03   0.541 ± 0.005   2334.31 s
//!   B=64   60.97 ± 0.30   0.506 ± 0.001   1243.81 s
//!
//! Expected shape: noticeably lower accuracy than clean MNIST (the 20%
//! uniform feature noise), B=32 above B=64, time ~ 1/B.
use dkkm::prelude::*;
use dkkm::util::stats::{bench_repeats, bench_scale, mean_std, pm, Table};

fn main() {
    let scale = bench_scale();
    let base = ((1600.0 * scale) as usize).max(200);
    let copies = 10;
    let n = base * copies;
    let repeats = bench_repeats();
    println!("== Tab.3: noisy synthetic MNIST, N={n} ({base} base x {copies} copies) ==");
    println!("(paper: 60000 x 20 = 1.2M samples; DKKM_SCALE=37.5, copies=20 for full size)\n");

    let mut table = Table::new(&["B", "Clustering accuracy", "NMI", "Execution time (s)"]);
    table.row(&["Baseline".into(), "—".into(), "—".into(), "—".into()]);
    for &b in &[32usize, 64] {
        let (mut acc, mut nm, mut tm) = (Vec::new(), Vec::new(), Vec::new());
        for r in 0..repeats {
            let rep = Experiment::on(DatasetSpec::NoisyMnist { base, copies })
                .clusters(10)
                .batches(b)
                .seed(300 + r as u64)
                .build()
                .expect("build")
                .fit()
                .expect("run");
            acc.push(rep.train_accuracy * 100.0);
            nm.push(rep.train_nmi);
            tm.push(rep.seconds.expect("timed run"));
        }
        let (am, astd) = mean_std(&acc);
        let (nmn, nstd) = mean_std(&nm);
        let (tmn, tstd) = mean_std(&tm);
        table.row(&[b.to_string(), pm(am, astd), pm(nmn, nstd), pm(tmn, tstd)]);
    }
    println!("{}", table.render());
    println!("shape check: accuracy below clean MNIST, B=32 >= B=64, time ~ 1/B (Tab.3).");
}
