//! bench-json harness: serve-loop latency and throughput.
//!
//! Fits a synthetic-MNIST session, freezes it into a `ServeModel`, and
//! drives the serve loop at 1-, 8- and 64-row request sizes (p50/p99
//! service latency per micro-batch from the loop's own counters), then
//! floods it with single-row queries under coalescing to measure QPS at
//! saturation. Emits `BENCH_serve.json` (override the path with
//! `DKKM_BENCH_OUT`). Every served label vector is equivalence-asserted
//! bit-for-bit against the model's direct assignment and label-for-
//! label against the serial scalar reference, so the bench doubles as a
//! smoke test: batching and coalescing must change the timings, never
//! the labels.
//!
//!     cargo bench --bench serve_json
//!
//! Knobs: `DKKM_SCALE` multiplies the query count, `DKKM_REPEATS` sets
//! timing repeats per request size.
use dkkm::coordinator::{assign_test_set_reference, DatasetSpec, Experiment};
use dkkm::kernels::KernelFn;
use dkkm::serve::{RowBlock, ServeLoop, ServeOptions};
use dkkm::util::json::Json;
use dkkm::util::stats::{bench_repeats, bench_scale, Table, Timer};

fn main() {
    // queries come from the held-out split; keep it a multiple of 64 so
    // every request size divides it evenly
    let n_q = ((((512.0 * bench_scale()) as usize).max(128) + 63) / 64) * 64;
    let n_train = 2_000usize;
    let c = 10usize;
    let repeats = bench_repeats();
    println!("== serve bench: synthetic MNIST train={n_train}, {n_q} query rows, C={c} ==\n");

    let session = Experiment::on(DatasetSpec::Mnist { train: n_train, test: n_q })
        .clusters(c)
        .batches(4)
        .seed(23)
        .build()
        .expect("build session");
    let report = session.fit().expect("fit");
    let model = session.serve_model(&report).expect("serve model");
    let train = session.train().expect("dense workload");
    let test = session.test().expect("held-out split");

    // the two references: bit-level (direct model assignment) and
    // label-level (the serial scalar oracle predating the serve path)
    let direct = model.assign_dense(&test.x).expect("direct assign");
    let oracle = assign_test_set_reference(
        test,
        train,
        &report.result.medoids,
        KernelFn::Rbf { gamma: session.gamma() },
    );
    assert_eq!(direct, oracle, "serve model diverged from the scalar oracle");

    let mut table = Table::new(&["request rows", "p50 us", "p99 us", "rows/s"]);
    let mut sizes = Vec::new();
    for &bs in &[1usize, 8, 64] {
        // one worker, coalescing capped at the request size: every
        // micro-batch is exactly `bs` rows, so the counter bucket is
        // the request size and p50/p99 are clean per-size service times
        let handle = ServeLoop::spawn(
            model.clone(),
            ServeOptions { workers: 1, max_batch_rows: bs },
        );
        let mut wall = f64::INFINITY;
        for _ in 0..repeats {
            let mut served = Vec::with_capacity(n_q);
            let t = Timer::start();
            for lo in (0..n_q).step_by(bs) {
                let idx: Vec<usize> = (lo..lo + bs).collect();
                let resp = handle
                    .assign(RowBlock::Dense(test.x.gather(&idx)))
                    .expect("serve");
                served.extend(resp.labels);
            }
            wall = wall.min(t.elapsed_s());
            assert_eq!(served, direct, "{bs}-row requests diverged from direct assign");
        }
        let snap = handle.counters();
        let (label, p50, p99) = snap
            .buckets
            .iter()
            .find(|(_, count, _, _)| *count > 0)
            .map(|(label, _, p50, p99)| (*label, *p50, *p99))
            .expect("latency bucket populated");
        let rows_per_s = n_q as f64 / wall;
        table.row(&[
            format!("{bs}"),
            format!("{p50:.1}"),
            format!("{p99:.1}"),
            format!("{rows_per_s:.0}"),
        ]);
        sizes.push(Json::obj(vec![
            ("request_rows", Json::num(bs as f64)),
            ("bucket", Json::str(label)),
            ("p50_us", Json::num(p50)),
            ("p99_us", Json::num(p99)),
            ("round_trip_rows_per_s", Json::num(rows_per_s)),
            ("service_qps", Json::num(snap.qps())),
        ]));
    }
    println!("{}", table.render());

    // saturation: flood single-row queries through a multi-worker loop
    // with coalescing on — micro-batches grow toward the cap and QPS is
    // rows over busy seconds
    let handle = ServeLoop::spawn(
        model.clone(),
        ServeOptions { workers: 4, max_batch_rows: 64 },
    );
    let mut served = vec![0usize; n_q];
    let receivers: Vec<_> = (0..n_q)
        .map(|r| handle.query(RowBlock::Dense(test.x.gather(&[r])), None))
        .collect();
    for (r, rx) in receivers.into_iter().enumerate() {
        let resp = rx.recv().expect("reply").expect("serve");
        served[r] = resp.labels[0];
    }
    assert_eq!(served, direct, "coalesced single-row flood diverged from direct assign");
    let sat = handle.counters();
    println!(
        "saturation: {:.0} rows/s over {} coalesced micro-batches ({} rows, {:.3}s busy)",
        sat.qps(),
        sat.batches,
        sat.rows,
        sat.busy_s
    );

    let report_json = Json::obj(vec![
        ("bench", Json::str("serve")),
        ("n_train", Json::num(n_train as f64)),
        ("n_queries", Json::num(n_q as f64)),
        ("c", Json::num(c as f64)),
        ("repeats", Json::num(repeats as f64)),
        ("equivalence", Json::str("bit-identical to direct assign; label-identical to scalar oracle")),
        ("sizes", Json::arr(sizes)),
        (
            "saturation",
            Json::obj(vec![
                ("workers", Json::num(4.0)),
                ("qps", Json::num(sat.qps())),
                ("micro_batches", Json::num(sat.batches as f64)),
                ("counters", sat.to_json()),
            ]),
        ),
    ]);
    let out = std::env::var("DKKM_BENCH_OUT").unwrap_or_else(|_| "BENCH_serve.json".into());
    std::fs::write(&out, report_json.to_string()).expect("write bench json");
    println!("\nwrote {out}");
}
