//! Strong-scaling simulator (Fig.6).
//!
//! The paper runs MNIST, B = 1, on 16..1024 BG/Q nodes and 16..256
//! NeXtScale nodes. We cannot run 1024 nodes, so execution time is
//! decomposed per §3.3 and each term is either *measured on this host*
//! (per-element kernel-evaluation and update-sweep throughput, via a
//! calibration probe on the actual dataset) or *modeled* (collectives,
//! via [`NetModel`]):
//!
//! T(P) = T_serial                                  (fetch + k-means++ init)
//!      + N L / P * t_kernel                        (Gram block, perfectly parallel)
//!      + iters * [ N L / P * t_update              (f + argmin sweep)
//!                + allreduce(C floats)             (g)
//!                + allgather(N/P labels) ]         (U)
//!
//! This is exactly the Amdahl structure the paper invokes to explain the
//! flattening at high P.
use crate::cluster::assign;
use crate::kernels::GramSource;
use crate::util::rng::Rng;
use crate::util::stats::Timer;

use super::netmodel::NetModel;

/// Measured per-element costs (seconds).
#[derive(Clone, Copy, Debug)]
pub struct Calibration {
    /// Kernel evaluation per Gram element.
    pub t_kernel: f64,
    /// Update sweep (f accumulate + argmin) per Gram element.
    pub t_update: f64,
    /// Serial prologue per sample (fetch + init assign + seeding share).
    pub t_serial_per_sample: f64,
}

/// One row of the scaling report.
#[derive(Clone, Copy, Debug)]
pub struct ScalingPoint {
    pub p: usize,
    pub total_s: f64,
    pub compute_s: f64,
    pub comm_s: f64,
    pub serial_s: f64,
    /// Speedup relative to p = 1 of the same model.
    pub speedup: f64,
    pub efficiency: f64,
}

/// Full report for one topology.
#[derive(Clone, Debug)]
pub struct ScalingReport {
    pub points: Vec<ScalingPoint>,
    pub calibration: Calibration,
}

/// The simulator: workload shape (N, L, C, inner iterations) + topology.
pub struct ScalingSimulator {
    pub net: NetModel,
    pub n: usize,
    pub l: usize,
    pub c: usize,
    pub iters: usize,
}

impl ScalingSimulator {
    /// Measure per-element throughputs on a representative probe of the
    /// dataset: `probe_rows x probe_cols` Gram block.
    pub fn calibrate(
        source: &dyn GramSource,
        probe_rows: usize,
        probe_cols: usize,
        seed: u64,
    ) -> Calibration {
        let mut rng = Rng::new(seed);
        let n = source.n();
        let rows = rng.sample_indices(n, probe_rows.min(n));
        let cols = rng.sample_indices(n, probe_cols.min(n));
        // kernel eval throughput (single-threaded shard's perspective)
        let timer = Timer::start();
        let block = source.block_mat(&rows, &cols);
        let t_kernel = timer.elapsed_s() / (rows.len() * cols.len()) as f64;
        // update sweep throughput
        let c = 10usize;
        let lm_labels: Vec<usize> = (0..cols.len()).map(|_| rng.below(c)).collect();
        let k_ll = source.block_mat(&cols, &cols);
        let timer = Timer::start();
        let reps = 3;
        for _ in 0..reps {
            let (_labels, _stats) =
                assign::inner_iteration(&block, &k_ll, &lm_labels, c);
        }
        let t_update = timer.elapsed_s()
            / (reps * (rows.len() + cols.len()) * cols.len()) as f64;
        // serial prologue: nearest-medoid init assign = C kernel evals per
        // sample plus fetch overhead; approximate with the measured kernel
        // throughput
        let t_serial_per_sample = t_kernel * c as f64 * 2.0;
        Calibration { t_kernel, t_update, t_serial_per_sample }
    }

    /// Predicted execution time decomposition at `p` nodes.
    pub fn time_at(&self, cal: &Calibration, p: usize) -> (f64, f64, f64) {
        let work_elems = (self.n as f64) * (self.l as f64);
        let shard_elems = work_elems / p as f64;
        let compute =
            shard_elems * cal.t_kernel + self.iters as f64 * shard_elems * cal.t_update;
        let labels_bytes_per_node = (self.n / p.max(1)) * 8;
        let comm = self.iters as f64
            * (self.net.allreduce(p, self.c * 4)
                + self.net.allgather(p, labels_bytes_per_node));
        let serial = self.n as f64 * cal.t_serial_per_sample;
        (compute, comm, serial)
    }

    /// Sweep node counts and produce the report.
    pub fn sweep(&self, cal: Calibration, ps: &[usize]) -> ScalingReport {
        let (c1, m1, s1) = self.time_at(&cal, 1);
        let t1 = c1 + m1 + s1;
        let points = ps
            .iter()
            .map(|&p| {
                let (compute_s, comm_s, serial_s) = self.time_at(&cal, p);
                let total_s = compute_s + comm_s + serial_s;
                ScalingPoint {
                    p,
                    total_s,
                    compute_s,
                    comm_s,
                    serial_s,
                    speedup: t1 / total_s,
                    efficiency: t1 / total_s / p as f64,
                }
            })
            .collect();
        ScalingReport { points, calibration: cal }
    }
}

/// Convenience: make a calibration without a dataset (unit tests).
pub fn synthetic_calibration() -> Calibration {
    Calibration { t_kernel: 2e-9, t_update: 4e-10, t_serial_per_sample: 5e-8 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distributed::netmodel::Topology;
    use crate::kernels::{KernelFn, VecGram};
    use crate::linalg::Mat;

    fn sim(topology: Topology) -> ScalingSimulator {
        ScalingSimulator {
            net: NetModel::new(topology),
            n: 60_000,
            l: 60_000,
            c: 10,
            iters: 20,
        }
    }

    #[test]
    fn near_ideal_scaling_midrange() {
        let s = sim(Topology::BgqTorus5D);
        let rep = s.sweep(synthetic_calibration(), &[16, 32, 64, 128, 256, 512, 1024]);
        // paper: near-perfect up to ~1024 on BG/Q for this workload size
        for pt in &rep.points {
            if pt.p <= 256 {
                assert!(
                    pt.efficiency > 0.7,
                    "efficiency at p={} is {}",
                    pt.p,
                    pt.efficiency
                );
            }
        }
        // monotone decreasing total time in the scaled range
        for w in rep.points.windows(2) {
            assert!(w[1].total_s < w[0].total_s, "{w:?}");
        }
    }

    #[test]
    fn amdahl_flattening_at_high_p() {
        let s = sim(Topology::InfinibandQdr);
        let rep = s.sweep(synthetic_calibration(), &[1, 64, 4096, 65536]);
        // efficiency must eventually collapse
        let last = rep.points.last().unwrap();
        assert!(last.efficiency < 0.5, "no flattening: {last:?}");
    }

    #[test]
    fn comm_share_grows_with_p() {
        let s = sim(Topology::BgqTorus5D);
        let cal = synthetic_calibration();
        let (_, m16, _) = s.time_at(&cal, 16);
        let (c16, _, _) = s.time_at(&cal, 16);
        let (c1024, m1024, _) = s.time_at(&cal, 1024);
        assert!(m1024 / c1024 > m16 / c16);
    }

    #[test]
    fn calibration_on_real_source_positive() {
        let mut rng = Rng::new(0);
        let x = Mat::from_fn(256, 16, |_, _| rng.normal32(0.0, 1.0));
        let g = VecGram::new(x, KernelFn::Rbf { gamma: 0.1 }, 1);
        let cal = ScalingSimulator::calibrate(&g, 128, 128, 1);
        assert!(cal.t_kernel > 0.0 && cal.t_kernel < 1e-3);
        assert!(cal.t_update > 0.0 && cal.t_update < 1e-3);
    }

    #[test]
    fn speedup_at_one_is_one() {
        let s = sim(Topology::BgqTorus5D);
        let rep = s.sweep(synthetic_calibration(), &[1, 2]);
        assert!((rep.points[0].speedup - 1.0).abs() < 1e-9);
    }
}
