//! bench-json harness: approximation engines vs the exact kernel path.
//!
//! Fits toy2d at three sizes of N on the `native` engine and on the
//! `nystrom:<rank>` / `rff:<d>` approximation engines at two ranks/D
//! each, recording the final clustering cost (all engines score the
//! same `cost_vs_medoids` observable in the *exact* kernel space, so
//! costs are directly comparable), embed time and fit time — the
//! cost-vs-time curves of the approximation family. Emits
//! `BENCH_approx.json` (override the path with `DKKM_BENCH_OUT`).
//!
//! The bench doubles as a smoke test: at the largest rank/D both
//! approximations must land within 1.05x of the native final cost at
//! every N, or the harness panics.
//!
//!     cargo bench --bench approx_json
//!
//! Knobs: `DKKM_SCALE` multiplies the dataset sizes, `DKKM_REPEATS`
//! sets timing repeats per (engine, N) cell.
use dkkm::coordinator::{DatasetSpec, EngineSpec, Experiment, RunReport};
use dkkm::util::json::Json;
use dkkm::util::stats::{bench_repeats, bench_scale, Table, Timer};

const C: usize = 4;
const SEED: u64 = 47;
const RANKS: [usize; 2] = [16, 64];
const DIMS: [usize; 2] = [64, 256];
const COST_TOLERANCE: f64 = 1.05;

/// One fitted cell: the report of the first fit (fits are
/// deterministic, so repeats only tighten the timing) and the best
/// wall time across repeats.
fn fit_cell(per_cluster: usize, spec: EngineSpec, repeats: usize) -> (RunReport, f64) {
    let mut wall = f64::INFINITY;
    let mut report = None;
    for _ in 0..repeats {
        let session = Experiment::on(DatasetSpec::Toy2d { per_cluster })
            .clusters(C)
            .batches(4)
            .restarts(2)
            .seed(SEED)
            .engine(spec)
            .build()
            .expect("build session");
        let t = Timer::start();
        let r = session.fit().expect("fit");
        wall = wall.min(t.elapsed_s());
        report.get_or_insert(r);
    }
    (report.expect("repeats >= 1"), wall)
}

fn cell_json(spec: EngineSpec, report: &RunReport, wall: f64, ratio: f64) -> Json {
    let (embed_s, rank, reconstruction) = report
        .approx
        .as_ref()
        .map(|a| (a.embed_seconds, a.rank as f64, a.reconstruction))
        .unwrap_or((0.0, 0.0, 0.0));
    Json::obj(vec![
        ("engine", Json::str(&spec.to_string())),
        ("cost", Json::num(report.best_cost)),
        ("cost_vs_native", Json::num(ratio)),
        ("train_accuracy", Json::num(report.train_accuracy)),
        ("fit_s", Json::num(wall)),
        ("embed_s", Json::num(embed_s)),
        ("rank_used", Json::num(rank)),
        ("reconstruction", Json::num(reconstruction)),
    ])
}

fn main() {
    // three sizes of N; the floor keeps the largest Nystrom rank
    // feasible (rank <= 4 * per_cluster train rows) even under tiny
    // DKKM_SCALE, without collapsing the sizes into one N
    let sizes: Vec<usize> = [100usize, 200, 400]
        .iter()
        .map(|&pc| ((pc as f64 * bench_scale()) as usize).max(RANKS[RANKS.len() - 1] / 4))
        .collect();
    let repeats = bench_repeats();
    println!(
        "== approx bench: toy2d N = {:?}, C={C}, ranks {RANKS:?}, D {DIMS:?} ==\n",
        sizes.iter().map(|pc| pc * 4).collect::<Vec<_>>()
    );

    let mut table = Table::new(&["n", "engine", "cost", "x native", "embed s", "fit s"]);
    let mut size_rows = Vec::new();
    for &per_cluster in &sizes {
        let n = per_cluster * 4;
        let mut specs = vec![EngineSpec::Native];
        specs.extend(RANKS.iter().map(|&rank| EngineSpec::Nystrom { rank }));
        specs.extend(DIMS.iter().map(|&d| EngineSpec::Rff { d }));

        let mut native_cost = f64::NAN;
        let mut curves = Vec::new();
        for &spec in &specs {
            let (report, wall) = fit_cell(per_cluster, spec, repeats);
            if matches!(spec, EngineSpec::Native) {
                native_cost = report.best_cost;
            }
            let ratio = report.best_cost / native_cost;
            let embed_s = report.approx.as_ref().map_or(0.0, |a| a.embed_seconds);
            table.row(&[
                format!("{n}"),
                spec.to_string(),
                format!("{:.4}", report.best_cost),
                format!("{ratio:.3}"),
                format!("{embed_s:.3}"),
                format!("{wall:.3}"),
            ]);
            // the smoke-test teeth: the richest approximation of each
            // family must match the exact engine's final cost
            let (last_rank, last_d) = (RANKS[RANKS.len() - 1], DIMS[DIMS.len() - 1]);
            let richest = matches!(spec, EngineSpec::Nystrom { rank } if rank == last_rank)
                || matches!(spec, EngineSpec::Rff { d } if d == last_d);
            if richest {
                assert!(
                    ratio <= COST_TOLERANCE,
                    "{spec} cost {:.4} exceeds {COST_TOLERANCE}x native {native_cost:.4} at n={n}",
                    report.best_cost
                );
            }
            curves.push(cell_json(spec, &report, wall, ratio));
        }
        size_rows.push(Json::obj(vec![
            ("n", Json::num(n as f64)),
            ("per_cluster", Json::num(per_cluster as f64)),
            ("curves", Json::arr(curves)),
        ]));
    }
    println!("{}", table.render());

    let report_json = Json::obj(vec![
        ("bench", Json::str("approx")),
        ("c", Json::num(C as f64)),
        ("repeats", Json::num(repeats as f64)),
        ("ranks", Json::arr(RANKS.iter().map(|&r| Json::num(r as f64)))),
        ("dims", Json::arr(DIMS.iter().map(|&d| Json::num(d as f64)))),
        ("cost_tolerance", Json::num(COST_TOLERANCE)),
        (
            "equivalence",
            Json::str("largest rank/D within 1.05x native cost at every N (asserted)"),
        ),
        ("sizes", Json::arr(size_rows)),
    ]);
    let out = std::env::var("DKKM_BENCH_OUT").unwrap_or_else(|_| "BENCH_approx.json".into());
    std::fs::write(&out, report_json.to_string()).expect("write bench json");
    println!("\nwrote {out}");
}
