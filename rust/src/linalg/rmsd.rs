//! Roto-translationally invariant frame comparison for MD clustering.
//!
//! The paper's flagship application clusters MD conformational frames
//! where "roto-translational invariance is mandatory" (§1). We implement
//! the minimal-RMSD distance two ways:
//!
//! * **Kabsch** via a cyclic Jacobi eigendecomposition of the 3x3
//!   covariance Gram matrix (robust reference),
//! * **QCP** (Theobald 2005): the minimal RMSD follows from the largest
//!   eigenvalue of a 4x4 key matrix, found by Newton iteration on the
//!   quartic characteristic polynomial — no eigenvectors needed, which is
//!   what the kernel-matrix hot loop wants.
//!
//! Both operate on centred coordinates; `Frame` stores `natoms x 3`.

/// One MD conformational frame: `natoms` rows of (x, y, z).
#[derive(Clone, Debug)]
pub struct Frame {
    pub coords: Vec<[f64; 3]>,
}

impl Frame {
    pub fn new(coords: Vec<[f64; 3]>) -> Frame {
        Frame { coords }
    }

    pub fn natoms(&self) -> usize {
        self.coords.len()
    }

    /// Centre the frame at its centroid (in place), returning the centroid.
    pub fn center(&mut self) -> [f64; 3] {
        let c = centroid(&self.coords);
        for p in &mut self.coords {
            for k in 0..3 {
                p[k] -= c[k];
            }
        }
        c
    }

    /// Flatten to an `f32` feature row (used where a plain vector-space
    /// embedding of the trajectory is needed, e.g. landmark medoids dump).
    pub fn flat32(&self) -> Vec<f32> {
        self.coords
            .iter()
            .flat_map(|p| p.iter().map(|&v| v as f32))
            .collect()
    }
}

/// Centroid of a coordinate set.
pub fn centroid(coords: &[[f64; 3]]) -> [f64; 3] {
    let n = coords.len() as f64;
    let mut c = [0.0; 3];
    for p in coords {
        for k in 0..3 {
            c[k] += p[k];
        }
    }
    for k in 0..3 {
        c[k] /= n;
    }
    c
}

fn centered(coords: &[[f64; 3]]) -> (Vec<[f64; 3]>, f64) {
    let c = centroid(coords);
    let mut out = Vec::with_capacity(coords.len());
    let mut g = 0.0; // inner self-product (sum of squares)
    for p in coords {
        let q = [p[0] - c[0], p[1] - c[1], p[2] - c[2]];
        g += q[0] * q[0] + q[1] * q[1] + q[2] * q[2];
        out.push(q);
    }
    (out, g)
}

/// 3x3 covariance matrix A = X^T Y over centred coordinate sets.
fn covariance(x: &[[f64; 3]], y: &[[f64; 3]]) -> [[f64; 3]; 3] {
    let mut a = [[0.0; 3]; 3];
    for (p, q) in x.iter().zip(y) {
        for i in 0..3 {
            for j in 0..3 {
                a[i][j] += p[i] * q[j];
            }
        }
    }
    a
}

/// Minimal RMSD between two frames via QCP (Theobald 2005).
///
/// Builds the 4x4 key matrix's characteristic quartic from the covariance
/// matrix and Newton-iterates from the upper bound (Ga+Gb)/2 down to the
/// largest eigenvalue. Handles reflections correctly (unlike naive Kabsch
/// without the determinant fix).
pub fn qcp_rmsd(a: &Frame, b: &Frame) -> f64 {
    assert_eq!(a.natoms(), b.natoms(), "frame size mismatch");
    let n = a.natoms() as f64;
    let (xa, ga) = centered(&a.coords);
    let (xb, gb) = centered(&b.coords);
    let m = covariance(&xa, &xb);

    // Characteristic polynomial coefficients (Theobald's expansion).
    let (sxx, sxy, sxz) = (m[0][0], m[0][1], m[0][2]);
    let (syx, syy, syz) = (m[1][0], m[1][1], m[1][2]);
    let (szx, szy, szz) = (m[2][0], m[2][1], m[2][2]);

    let sxx2 = sxx * sxx;
    let syy2 = syy * syy;
    let szz2 = szz * szz;
    let sxy2 = sxy * sxy;
    let syz2 = syz * syz;
    let sxz2 = sxz * sxz;
    let syx2 = syx * syx;
    let szy2 = szy * szy;
    let szx2 = szx * szx;

    let syzszymsyyszz2 = 2.0 * (syz * szy - syy * szz);
    let sxx2syy2szz2syz2szy2 = syy2 + szz2 - sxx2 + syz2 + szy2;

    let c2 = -2.0 * (sxx2 + syy2 + szz2 + sxy2 + syx2 + sxz2 + szx2 + syz2 + szy2);
    let c1 = 8.0
        * (sxx * syz * szy + syy * szx * sxz + szz * sxy * syx
            - sxx * syy * szz
            - syz * szx * sxy
            - szy * syx * sxz);

    let d = (sxy2 + sxz2 - syx2 - szx2) * (sxy2 + sxz2 - syx2 - szx2);
    let e = (sxx2syy2szz2syz2szy2 + syzszymsyyszz2)
        * (sxx2syy2szz2syz2szy2 - syzszymsyyszz2);
    let f = (-(sxz + szx) * (syz - szy) + (sxy - syx) * (sxx - syy - szz))
        * (-(sxz - szx) * (syz + szy) + (sxy - syx) * (sxx - syy + szz));
    let g = (-(sxz + szx) * (syz + szy) - (sxy + syx) * (sxx + syy - szz))
        * (-(sxz - szx) * (syz - szy) - (sxy + syx) * (sxx + syy + szz));
    let h = ((sxy + syx) * (syz + szy) + (sxz + szx) * (sxx - syy + szz))
        * (-(sxy - syx) * (syz - szy) + (sxz + szx) * (sxx + syy + szz));
    let i = ((sxy + syx) * (syz - szy) + (sxz - szx) * (sxx - syy - szz))
        * (-(sxy - syx) * (syz + szy) + (sxz - szx) * (sxx + syy - szz));
    let c0 = d + e + f + g + h + i;

    // Newton from the upper bound; the largest root is <= (Ga+Gb)/2.
    let mut lambda = 0.5 * (ga + gb);
    for _ in 0..64 {
        let l2 = lambda * lambda;
        let p = l2 * l2 + c2 * l2 + c1 * lambda + c0;
        let dp = 4.0 * lambda * l2 + 2.0 * c2 * lambda + c1;
        if dp.abs() < 1e-300 {
            break;
        }
        let step = p / dp;
        lambda -= step;
        if step.abs() < 1e-11 * lambda.abs().max(1.0) {
            break;
        }
    }
    let msd = ((ga + gb - 2.0 * lambda) / n).max(0.0);
    msd.sqrt()
}

/// Minimal RMSD via explicit Kabsch rotation (Jacobi eigendecomposition of
/// A^T A). Slower but fully explicit; used as the test oracle for QCP.
pub fn kabsch_rmsd(a: &Frame, b: &Frame) -> f64 {
    assert_eq!(a.natoms(), b.natoms());
    let n = a.natoms() as f64;
    let (xa, ga) = centered(&a.coords);
    let (xb, gb) = centered(&b.coords);
    let m = covariance(&xa, &xb); // A = Xa^T Xb

    // B = A^T A (symmetric PSD)
    let mut bmat = [[0.0; 3]; 3];
    for i in 0..3 {
        for j in 0..3 {
            for k in 0..3 {
                bmat[i][j] += m[k][i] * m[k][j];
            }
        }
    }
    let (evals, _evecs) = jacobi3(bmat);
    // singular values of A
    let mut sv: Vec<f64> = evals.iter().map(|&l| l.max(0.0).sqrt()).collect();
    sv.sort_by(|x, y| y.partial_cmp(x).unwrap());
    let det = det3(&m);
    let trace = if det < 0.0 {
        sv[0] + sv[1] - sv[2]
    } else {
        sv[0] + sv[1] + sv[2]
    };
    let msd = ((ga + gb - 2.0 * trace) / n).max(0.0);
    msd.sqrt()
}

fn det3(m: &[[f64; 3]; 3]) -> f64 {
    m[0][0] * (m[1][1] * m[2][2] - m[1][2] * m[2][1])
        - m[0][1] * (m[1][0] * m[2][2] - m[1][2] * m[2][0])
        + m[0][2] * (m[1][0] * m[2][1] - m[1][1] * m[2][0])
}

/// Cyclic Jacobi eigendecomposition of a symmetric 3x3 matrix.
/// Returns (eigenvalues, eigenvectors-as-columns).
fn jacobi3(mut a: [[f64; 3]; 3]) -> ([f64; 3], [[f64; 3]; 3]) {
    let mut v = [[0.0; 3]; 3];
    for (i, row) in v.iter_mut().enumerate() {
        row[i] = 1.0;
    }
    for _sweep in 0..50 {
        let off = a[0][1] * a[0][1] + a[0][2] * a[0][2] + a[1][2] * a[1][2];
        if off < 1e-30 {
            break;
        }
        for p in 0..2 {
            for q in (p + 1)..3 {
                if a[p][q].abs() < 1e-300 {
                    continue;
                }
                let theta = (a[q][q] - a[p][p]) / (2.0 * a[p][q]);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // rotate a
                for k in 0..3 {
                    let akp = a[k][p];
                    let akq = a[k][q];
                    a[k][p] = c * akp - s * akq;
                    a[k][q] = s * akp + c * akq;
                }
                for k in 0..3 {
                    let apk = a[p][k];
                    let aqk = a[q][k];
                    a[p][k] = c * apk - s * aqk;
                    a[q][k] = s * apk + c * aqk;
                }
                for k in 0..3 {
                    let vkp = v[k][p];
                    let vkq = v[k][q];
                    v[k][p] = c * vkp - s * vkq;
                    v[k][q] = s * vkp + c * vkq;
                }
            }
        }
    }
    ([a[0][0], a[1][1], a[2][2]], v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_frame(rng: &mut Rng, natoms: usize) -> Frame {
        Frame::new(
            (0..natoms)
                .map(|_| [rng.normal() * 2.0, rng.normal() * 2.0, rng.normal() * 2.0])
                .collect(),
        )
    }

    /// Random rotation matrix from a random unit quaternion.
    fn random_rotation(rng: &mut Rng) -> [[f64; 3]; 3] {
        let (mut q, mut norm) = ([0.0; 4], 0.0);
        for v in &mut q {
            *v = rng.normal();
        }
        for v in &q {
            norm += v * v;
        }
        let norm = norm.sqrt();
        for v in &mut q {
            *v /= norm;
        }
        let (w, x, y, z) = (q[0], q[1], q[2], q[3]);
        [
            [1.0 - 2.0 * (y * y + z * z), 2.0 * (x * y - w * z), 2.0 * (x * z + w * y)],
            [2.0 * (x * y + w * z), 1.0 - 2.0 * (x * x + z * z), 2.0 * (y * z - w * x)],
            [2.0 * (x * z - w * y), 2.0 * (y * z + w * x), 1.0 - 2.0 * (x * x + y * y)],
        ]
    }

    fn transform(f: &Frame, r: &[[f64; 3]; 3], t: &[f64; 3]) -> Frame {
        Frame::new(
            f.coords
                .iter()
                .map(|p| {
                    let mut q = [0.0; 3];
                    for i in 0..3 {
                        q[i] = r[i][0] * p[0] + r[i][1] * p[1] + r[i][2] * p[2] + t[i];
                    }
                    q
                })
                .collect(),
        )
    }

    #[test]
    fn identical_frames_zero_rmsd() {
        let mut rng = Rng::new(0);
        let f = random_frame(&mut rng, 20);
        assert!(qcp_rmsd(&f, &f) < 1e-7);
        assert!(kabsch_rmsd(&f, &f) < 1e-7);
    }

    #[test]
    fn invariant_under_rigid_motion_property() {
        // property test: RMSD(a, R a + t) == 0 for 30 random rigid motions
        let mut rng = Rng::new(1);
        for case in 0..30 {
            let f = random_frame(&mut rng, 15);
            let r = random_rotation(&mut rng);
            let t = [rng.normal() * 10.0, rng.normal() * 10.0, rng.normal() * 10.0];
            let g = transform(&f, &r, &t);
            let d = qcp_rmsd(&f, &g);
            assert!(d < 1e-6, "case {case}: rmsd {d}");
        }
    }

    #[test]
    fn rmsd_of_rigidly_moved_pair_unchanged_property() {
        // RMSD(a, b) == RMSD(Ra+t, b) — invariance in the first argument
        let mut rng = Rng::new(2);
        for _ in 0..20 {
            let a = random_frame(&mut rng, 12);
            let b = random_frame(&mut rng, 12);
            let base = qcp_rmsd(&a, &b);
            let r = random_rotation(&mut rng);
            let t = [rng.normal(), rng.normal(), rng.normal()];
            let a2 = transform(&a, &r, &t);
            let moved = qcp_rmsd(&a2, &b);
            assert!((base - moved).abs() < 1e-6, "{base} vs {moved}");
        }
    }

    #[test]
    fn qcp_matches_kabsch_property() {
        let mut rng = Rng::new(3);
        for _ in 0..25 {
            let a = random_frame(&mut rng, 18);
            let b = random_frame(&mut rng, 18);
            let q = qcp_rmsd(&a, &b);
            let k = kabsch_rmsd(&a, &b);
            assert!((q - k).abs() < 1e-6, "qcp {q} vs kabsch {k}");
        }
    }

    #[test]
    fn symmetric() {
        let mut rng = Rng::new(4);
        for _ in 0..10 {
            let a = random_frame(&mut rng, 10);
            let b = random_frame(&mut rng, 10);
            assert!((qcp_rmsd(&a, &b) - qcp_rmsd(&b, &a)).abs() < 1e-8);
        }
    }

    #[test]
    fn known_displacement() {
        // two atoms displaced along x by 2: after centering both frames
        // are identical, so min RMSD is 0; but scaling one frame is not a
        // rigid motion, so RMSD > 0.
        let a = Frame::new(vec![[0.0, 0.0, 0.0], [1.0, 0.0, 0.0]]);
        let shifted = Frame::new(vec![[2.0, 0.0, 0.0], [3.0, 0.0, 0.0]]);
        assert!(qcp_rmsd(&a, &shifted) < 1e-7);
        let scaled = Frame::new(vec![[0.0, 0.0, 0.0], [3.0, 0.0, 0.0]]);
        assert!(qcp_rmsd(&a, &scaled) > 0.5);
    }

    #[test]
    fn reflection_not_allowed() {
        // a chiral 4-point set and its mirror image: proper rotations
        // cannot superpose them, so RMSD must stay > 0.
        let a = Frame::new(vec![
            [0.0, 0.0, 0.0],
            [1.0, 0.0, 0.0],
            [0.0, 1.0, 0.0],
            [0.0, 0.0, 1.0],
        ]);
        let mirror = Frame::new(
            a.coords.iter().map(|p| [-p[0], p[1], p[2]]).collect(),
        );
        let d = qcp_rmsd(&a, &mirror);
        let dk = kabsch_rmsd(&a, &mirror);
        assert!(d > 0.1, "qcp treated mirror as rotation: {d}");
        assert!((d - dk).abs() < 1e-6);
    }

    #[test]
    fn triangle_inequality_sampled() {
        let mut rng = Rng::new(5);
        for _ in 0..15 {
            let a = random_frame(&mut rng, 8);
            let b = random_frame(&mut rng, 8);
            let c = random_frame(&mut rng, 8);
            let ab = qcp_rmsd(&a, &b);
            let bc = qcp_rmsd(&b, &c);
            let ac = qcp_rmsd(&a, &c);
            assert!(ac <= ab + bc + 1e-6);
        }
    }
}
