//! Dataset substrates + mini-batch samplers.
//!
//! The paper evaluates on MNIST, RCV1, noisy MNIST, a 2D toy set, and an
//! MD trajectory. No network access exists here, so each real dataset is
//! replaced by a *synthetic generator that preserves the properties the
//! algorithm is sensitive to* (DESIGN.md §3 documents each substitution).
//! Generators are deterministic in their seed, so every EXPERIMENTS.md row
//! is reproducible.
mod dataset;
mod mnist;
mod rcv1;
mod sampler;
mod sparse;
mod toy2d;

pub use dataset::Dataset;
pub use mnist::{synthetic_mnist, noisy_mnist};
pub use rcv1::{random_projection, rcv1_vocab, synthetic_rcv1, synthetic_rcv1_sparse};
pub use sampler::{Sampling, minibatch_indices};
pub use sparse::{sparse_dot, CsrMat, SparseDataset};
pub use toy2d::toy2d;
