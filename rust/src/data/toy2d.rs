//! The paper's 2D toy dataset (§4, "2D Toy"): 4 Gaussian clusters of
//! 10000 elements each in [0,1]^2, centres on a grid, sigma = 0.2 widths
//! scaled down to keep clusters visually separable (the paper lists
//! sigma=[0.2,0.2] with unit-square means; we keep their centres and use
//! the width as given — overlap is part of the exercise).
use super::Dataset;
use crate::linalg::Mat;
use crate::util::rng::Rng;

/// Generate the 4-cluster 2D toy set. `per_cluster` = 10_000 reproduces
/// the paper's size; tests use smaller values.
pub fn toy2d(rng: &mut Rng, per_cluster: usize) -> Dataset {
    // paper lists three centres explicitly and omits the fourth; the grid
    // completion (0.75, 0.25) is the only symmetric choice.
    let centers: [[f32; 2]; 4] =
        [[0.25, 0.75], [0.75, 0.75], [0.25, 0.25], [0.75, 0.25]];
    let sigma = 0.08f32; // keeps the 0.5-spaced grid resolvable, as in Fig.4
    let n = per_cluster * 4;
    let mut x = Mat::zeros(n, 2);
    let mut y = vec![0usize; n];
    // interleave clusters, then shuffle sample order
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    for (slot, &i) in order.iter().enumerate() {
        let c = i % 4;
        x.set(slot, 0, rng.normal32(centers[c][0], sigma));
        x.set(slot, 1, rng.normal32(centers[c][1], sigma));
        y[slot] = c;
    }
    Dataset::new("toy2d", x, y, 4)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_classes() {
        let mut rng = Rng::new(0);
        let d = toy2d(&mut rng, 100);
        assert_eq!(d.n(), 400);
        assert_eq!(d.d(), 2);
        assert_eq!(d.classes, 4);
        for c in 0..4 {
            assert_eq!(d.y.iter().filter(|&&v| v == c).count(), 100);
        }
    }

    #[test]
    fn cluster_means_near_centers() {
        let mut rng = Rng::new(1);
        let d = toy2d(&mut rng, 2000);
        let centers = [[0.25, 0.75], [0.75, 0.75], [0.25, 0.25], [0.75, 0.25]];
        for c in 0..4 {
            let pts: Vec<&[f32]> = (0..d.n())
                .filter(|&i| d.y[i] == c)
                .map(|i| d.x.row(i))
                .collect();
            let mx: f32 = pts.iter().map(|p| p[0]).sum::<f32>() / pts.len() as f32;
            let my: f32 = pts.iter().map(|p| p[1]).sum::<f32>() / pts.len() as f32;
            assert!((mx - centers[c][0]).abs() < 0.02, "cluster {c}: {mx}");
            assert!((my - centers[c][1]).abs() < 0.02, "cluster {c}: {my}");
        }
    }

    #[test]
    fn deterministic() {
        let a = toy2d(&mut Rng::new(7), 50);
        let b = toy2d(&mut Rng::new(7), 50);
        assert_eq!(a.x.data(), b.x.data());
        assert_eq!(a.y, b.y);
    }
}
