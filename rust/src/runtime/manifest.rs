//! `artifacts/manifest.json` schema (written by python/compile/aot.py).
use std::path::{Path, PathBuf};

use crate::util::error::{Error, Result};
use crate::util::json::Json;

/// Tensor dtype in the manifest.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

/// One lowered executable.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<(DType, Vec<usize>)>,
    pub outputs: Vec<(DType, Vec<usize>)>,
    /// Free-form parameters (kind, tile sizes, d, l, c...).
    pub params: Json,
}

impl ArtifactEntry {
    /// Parameter lookup with error context.
    pub fn param(&self, key: &str) -> Result<usize> {
        self.params.req_usize(key)
    }
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<ArtifactEntry>,
}

fn parse_shape(j: &Json) -> Result<(DType, Vec<usize>)> {
    let arr = j
        .as_arr()
        .ok_or_else(|| Error::Config("shape entry not an array".into()))?;
    let dt = match arr.first().and_then(|d| d.as_str()) {
        Some("f32") => DType::F32,
        Some("i32") => DType::I32,
        other => return Err(Error::Config(format!("bad dtype {other:?}"))),
    };
    let dims = arr
        .get(1)
        .and_then(|d| d.as_arr())
        .ok_or_else(|| Error::Config("missing dims".into()))?
        .iter()
        .map(|d| d.as_usize().ok_or_else(|| Error::Config("bad dim".into())))
        .collect::<Result<Vec<_>>>()?;
    Ok((dt, dims))
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json")).map_err(|e| {
            Error::Config(format!(
                "cannot read {}/manifest.json (run `make artifacts`): {e}",
                dir.display()
            ))
        })?;
        let root = Json::parse(&text)?;
        let version = root.req_usize("version")?;
        if version != 1 {
            return Err(Error::Config(format!("unsupported manifest version {version}")));
        }
        let mut entries = Vec::new();
        for e in root
            .req("entries")?
            .as_arr()
            .ok_or_else(|| Error::Config("entries not an array".into()))?
        {
            let name = e.req_str("name")?.to_string();
            let file = dir.join(e.req_str("file")?);
            let inputs = e
                .req("inputs")?
                .as_arr()
                .ok_or_else(|| Error::Config("inputs not an array".into()))?
                .iter()
                .map(parse_shape)
                .collect::<Result<Vec<_>>>()?;
            let outputs = e
                .req("outputs")?
                .as_arr()
                .ok_or_else(|| Error::Config("outputs not an array".into()))?
                .iter()
                .map(parse_shape)
                .collect::<Result<Vec<_>>>()?;
            let params = e.req("params")?.clone();
            entries.push(ArtifactEntry { name, file, inputs, outputs, params });
        }
        Ok(Manifest { dir: dir.to_path_buf(), entries })
    }

    pub fn find(&self, name: &str) -> Result<&ArtifactEntry> {
        self.entries
            .iter()
            .find(|e| e.name == name)
            .ok_or_else(|| Error::Config(format!("artifact '{name}' not in manifest")))
    }

    /// The rbf kernel-tile entry for feature dimension `d`, if lowered.
    pub fn rbf_for_dim(&self, d: usize) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| {
            e.params.get("kind").and_then(|k| k.as_str()) == Some("rbf")
                && e.params.get("d").and_then(|v| v.as_usize()) == Some(d)
        })
    }

    /// Smallest fused inner-iteration entry whose landmark capacity fits
    /// `l` (n rows are chunked, c is padded).
    pub fn inner_for(&self, l: usize) -> Option<&ArtifactEntry> {
        self.entries
            .iter()
            .filter(|e| e.params.get("kind").and_then(|k| k.as_str()) == Some("inner"))
            .filter(|e| e.params.get("l").and_then(|v| v.as_usize()).unwrap_or(0) >= l)
            .min_by_key(|e| e.params.get("l").and_then(|v| v.as_usize()).unwrap_or(0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// `None` when `make artifacts` never ran on this checkout — the
    /// manifest-shape tests skip instead of failing the whole suite.
    fn manifest_or_skip() -> Option<Manifest> {
        let m = Manifest::load(&artifacts_dir());
        if m.is_err() {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
        }
        m.ok()
    }

    #[test]
    fn loads_real_manifest() {
        let Some(m) = manifest_or_skip() else { return };
        assert!(m.entries.len() >= 10);
        let rbf = m.find("rbf_t256_d784").unwrap();
        assert_eq!(rbf.inputs.len(), 3);
        assert_eq!(rbf.inputs[0].1, vec![256, 784]);
        assert_eq!(rbf.outputs[0].1, vec![256, 256]);
        assert_eq!(rbf.inputs[0].0, DType::F32);
    }

    #[test]
    fn rbf_lookup_by_dim() {
        let Some(m) = manifest_or_skip() else { return };
        assert!(m.rbf_for_dim(784).is_some());
        assert!(m.rbf_for_dim(2).is_some());
        assert!(m.rbf_for_dim(999).is_none());
    }

    #[test]
    fn inner_lookup_picks_smallest_fitting() {
        let Some(m) = manifest_or_skip() else { return };
        let e = m.inner_for(100).unwrap();
        assert_eq!(e.param("l").unwrap(), 256);
        let e = m.inner_for(256).unwrap();
        assert_eq!(e.param("l").unwrap(), 256);
        let e = m.inner_for(257).unwrap();
        assert_eq!(e.param("l").unwrap(), 1024);
        assert!(m.inner_for(4096).is_none());
    }

    #[test]
    fn missing_artifact_is_config_error() {
        let Some(m) = manifest_or_skip() else { return };
        assert!(m.find("nope").is_err());
    }

    #[test]
    fn missing_dir_good_error() {
        let err = Manifest::load(Path::new("/nonexistent")).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("make artifacts"), "{msg}");
    }
}
