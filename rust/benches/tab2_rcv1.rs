//! Tab.2 — RCV1: accuracy, NMI, execution time for B in {4, 16, 64}.
//!
//! Paper (188k docs -> 256-d random projection, heavily imbalanced
//! categories; accuracy sits in the ~16% regime for every method):
//!   Literature 16.59 ± 0.62   0.2737 ± 0.0063     —
//!   Baseline   15.16 ± 0.81   0.091  ± 0.0052     —
//!   B=4        17.41 ± 0.83   0.147  ± 0.006   797.65 s
//!   B=16       16.52 ± 0.74   0.145  ± 0.001   170.96 s
//!   B=64       16.15 ± 0.60   0.132  ± 0.001    77.20 s
//!
//! Expected shape on the synthetic corpus: low absolute accuracy (hard,
//! imbalanced regime), kernel k-means ahead of the linear baseline on
//! NMI, accuracy ~flat-to-slightly-decreasing in B, time ~ 1/B.
use dkkm::coordinator::run_lloyd_baseline;
use dkkm::prelude::*;
use dkkm::util::stats::{bench_repeats, bench_scale, mean_std, pm, Table};

fn main() {
    let scale = bench_scale();
    let n = ((8000.0 * scale) as usize).max(1000);
    let classes = 24;
    let dim = 256;
    let repeats = bench_repeats();
    println!("== Tab.2: synthetic RCV1, N={n}, {classes} imbalanced classes, dim={dim} ==");
    println!("(paper: N=188000, ~50 classes; DKKM_SCALE=23.5 for full size)\n");

    let c = 16; // elbow-scale choice for the scaled corpus
    let mut table = Table::new(&["B", "Clustering accuracy", "NMI", "Execution time (s)"]);

    let (mut acc, mut nm) = (Vec::new(), Vec::new());
    for r in 0..repeats {
        let spec = DatasetSpec::Rcv1 { n, classes, dim, storage: RcvStorage::Dense };
        let (_, _, a, nmv) = run_lloyd_baseline(&spec, c, 200 + r as u64).expect("baseline");
        acc.push(a.unwrap() * 100.0);
        nm.push(nmv.unwrap());
    }
    let (am, astd) = mean_std(&acc);
    let (nmn, nstd) = mean_std(&nm);
    table.row(&["Baseline".into(), pm(am, astd), pm(nmn, nstd), "—".into()]);

    for &b in &[4usize, 16, 64] {
        let (mut acc, mut nm, mut tm) = (Vec::new(), Vec::new(), Vec::new());
        for r in 0..repeats {
            let spec = DatasetSpec::Rcv1 { n, classes, dim, storage: RcvStorage::Dense };
            let rep = Experiment::on(spec)
                .clusters(c)
                .batches(b)
                .seed(200 + r as u64)
                .build()
                .expect("build")
                .fit()
                .expect("run");
            acc.push(rep.test_accuracy.unwrap() * 100.0);
            nm.push(rep.test_nmi.unwrap());
            tm.push(rep.seconds.expect("timed run"));
        }
        let (am, astd) = mean_std(&acc);
        let (nmn, nstd) = mean_std(&nm);
        let (tmn, tstd) = mean_std(&tm);
        table.row(&[b.to_string(), pm(am, astd), pm(nmn, nstd), pm(tmn, tstd)]);
    }
    println!("{}", table.render());
    println!("shape check: hard low-accuracy regime; kernel method >= linear baseline");
    println!("on NMI; execution time ~ 1/B (paper Tab.2).");
}
